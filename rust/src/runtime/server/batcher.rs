//! Micro-batching: many concurrent connections → few
//! [`TreeBundle::decide_batch`] calls.
//!
//! Connection threads enqueue one [`Job`] per decide request into a
//! bounded queue and block on a rendezvous channel for the outcome. A
//! single batcher thread drains the queue with a classic size/time
//! window: a flush happens as soon as `batch_max` jobs are pending, or
//! `batch_window` after the first job of the batch arrived — whichever
//! comes first. An idle daemon parks on a condvar (no spinning), and the
//! window only opens once a first job exists, so a lone request pays at
//! most `batch_window` on top of its socket round-trip — comparable to
//! a loopback RTT at the 200µs default. That is the classic
//! micro-batching trade (latency for occupancy): a strictly sequential
//! caller can set `--batch-window-us 0`, which flushes as soon as the
//! queue drains and leaves only the batching that arises naturally from
//! requests queueing while a dispatch is in progress.
//!
//! Every flush groups jobs by variant, snapshots each variant's bundle
//! epoch **once** ([`ReloadableBundle::get`]), and dispatches the whole
//! group through one `decide_batch` call (single-row groups take the
//! memoized scalar [`TreeBundle::decide`] path instead, so repeated
//! hot-shape traffic still hits the input cache). Grouping by variant
//! also makes reloads race-free: all rows of a group are decided — and
//! their responses fingerprinted — by the same epoch.
//!
//! Correctness: rows are pure functions of the input, `decide_batch` is
//! bit-identical to scalar `decide` at any thread count, and the memo
//! cache can only return what the uncached path computes — so a batched
//! daemon answer is bit-identical to an in-process `decide` on the same
//! epoch, regardless of traffic interleaving.

use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::mpsc::SyncSender;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::ServedVariant;
use crate::util::failpoint::{self, sites};

/// One queued decide request.
pub struct Job {
    pub variant: Arc<ServedVariant>,
    pub input: Vec<f64>,
    pub enqueued: Instant,
    /// Rendezvous back to the connection thread (capacity-1 channel: the
    /// send never blocks; a vanished client just drops the receiver).
    pub reply: SyncSender<Outcome>,
}

/// What the batcher sends back for one job.
pub type Outcome = Result<DecideOk, String>;

/// A successful decision, carrying everything the connection thread
/// needs to build the response without touching the (possibly already
/// swapped) bundle slot again.
#[derive(Clone, Debug)]
pub struct DecideOk {
    /// Design-parameter names, in design-space order (shared by every
    /// row of a dispatch — refcount bump, not a per-row deep clone).
    pub names: Arc<[String]>,
    /// Chosen config values, same order (the bit-exact payload).
    pub values: Vec<f64>,
    /// Fingerprint of the bundle epoch that decided this row (shared
    /// across the dispatch like `names`).
    pub fingerprint: Option<Arc<str>>,
    /// How many rows rode in the dispatch that served this row.
    pub batch: usize,
}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

/// Why a push was refused (the queue never blocks producers).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PushError {
    /// The queue is at capacity: the job was **shed**, not queued. The
    /// daemon turns this into a structured `overloaded` error response
    /// with a retry-after hint; carrying the capacity lets it size the
    /// hint from the drain rate.
    Overloaded { capacity: usize },
    /// The daemon is shutting down; nothing new is accepted.
    ShuttingDown,
    /// An armed `batcher.enqueue` failpoint fired (chaos testing).
    Injected(String),
}

impl std::fmt::Display for PushError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PushError::Overloaded { capacity } => {
                write!(f, "daemon is overloaded ({capacity} requests queued)")
            }
            PushError::ShuttingDown => f.write_str("daemon is shutting down"),
            PushError::Injected(msg) => f.write_str(msg),
        }
    }
}

/// The bounded job queue + the batcher loop that drains it.
pub struct BatchQueue {
    state: Mutex<QueueState>,
    /// Producers signal arrivals; the batcher also waits here for its
    /// time window.
    added: Condvar,
    capacity: usize,
}

impl BatchQueue {
    pub fn new(capacity: usize) -> Arc<BatchQueue> {
        Arc::new(BatchQueue {
            state: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            added: Condvar::new(),
            capacity: capacity.max(1),
        })
    }

    /// Poison-tolerant state lock: the queue is a plain deque + flag,
    /// structurally valid at every instruction boundary, so a panic on
    /// some other thread (injected by the chaos suite or real) must not
    /// cascade into wedging every producer and the batcher forever.
    fn lock_state(&self) -> MutexGuard<'_, QueueState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Try to enqueue a job. Never blocks: a full queue sheds the job
    /// with [`PushError::Overloaded`] instead of wedging the connection
    /// thread — under saturation, blocking producers would turn one
    /// slow consumer into daemon-wide head-of-line blocking, whereas a
    /// shed is answered immediately and the client retries after the
    /// hinted delay.
    pub fn push(&self, job: Job) -> Result<(), PushError> {
        if let Err(e) = failpoint::fail(sites::BATCHER_ENQUEUE) {
            return Err(PushError::Injected(e));
        }
        let mut st = self.lock_state();
        if st.shutdown {
            return Err(PushError::ShuttingDown);
        }
        if st.jobs.len() >= self.capacity {
            return Err(PushError::Overloaded { capacity: self.capacity });
        }
        st.jobs.push_back(job);
        drop(st);
        self.added.notify_all();
        Ok(())
    }

    /// Current queue depth (diagnostics; racy by nature).
    pub fn depth(&self) -> usize {
        self.lock_state().jobs.len()
    }

    /// Stop the batcher after it drains what is already queued.
    pub fn shutdown(&self) {
        self.lock_state().shutdown = true;
        self.added.notify_all();
    }

    /// The batcher thread body: collect → flush until shutdown.
    /// `threads` is passed through to `decide_batch` (0 = adaptive).
    /// May unwind (a panicking tree traversal, an armed `batcher.flush`
    /// failpoint): the daemon runs it under a supervisor that catches
    /// the panic and calls `run` again, and the queue state stays valid
    /// because `flush` executes outside the lock.
    pub fn run(&self, batch_max: usize, batch_window: Duration, threads: usize) {
        let batch_max = batch_max.max(1);
        loop {
            let mut batch: Vec<Job> = Vec::with_capacity(batch_max);
            {
                let mut st = self.lock_state();
                while st.jobs.is_empty() && !st.shutdown {
                    st = self.added.wait(st).unwrap_or_else(|e| e.into_inner());
                }
                if st.jobs.is_empty() {
                    // Shutdown with nothing queued: done.
                    return;
                }
                // A first job opened the window.
                let deadline = Instant::now() + batch_window;
                loop {
                    while batch.len() < batch_max {
                        match st.jobs.pop_front() {
                            Some(j) => batch.push(j),
                            None => break,
                        }
                    }
                    if batch.len() >= batch_max || st.shutdown {
                        break;
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (guard, timeout) = self
                        .added
                        .wait_timeout(st, deadline - now)
                        .unwrap_or_else(|e| e.into_inner());
                    st = guard;
                    if timeout.timed_out() && st.jobs.is_empty() {
                        break;
                    }
                }
            }
            flush(batch, threads);
        }
    }
}

/// Dispatch one collected batch: group by variant, one bundle snapshot
/// and one (batched or memoized-scalar) decide per group, then answer
/// every job.
fn flush(batch: Vec<Job>, threads: usize) {
    // Supervisor test hook: a `panic` fault here unwinds out of `run`
    // into the daemon's batcher supervisor (which restarts the loop);
    // an `err` fault aborts this flush. Either way the batch's reply
    // senders drop, so every affected connection gets an explicit
    // dropped-request error — never a hang.
    if failpoint::fail(sites::BATCHER_FLUSH).is_err() {
        return;
    }
    let now = Instant::now();
    // Group by variant identity (the Arc pointer): no per-job key
    // allocation on the hot path, and jobs of one variant always share
    // one `Arc<ServedVariant>` handed out by `ServedRegistry::resolve`.
    let mut groups: BTreeMap<*const ServedVariant, Vec<Job>> = BTreeMap::new();
    for job in batch {
        groups.entry(Arc::as_ptr(&job.variant)).or_default().push(job);
    }
    for jobs in groups.into_values() {
        let variant = jobs[0].variant.clone();
        let stats = &variant.stats;
        stats.requests.fetch_add(jobs.len() as u64, Ordering::Relaxed);
        let queue_ns: u64 = jobs
            .iter()
            .map(|j| now.saturating_duration_since(j.enqueued).as_nanos() as u64)
            .sum();
        stats.queue_ns.fetch_add(queue_ns, Ordering::Relaxed);
        stats.window.record(jobs.len() as u64, 0, 0, queue_ns);

        // One epoch snapshot decides (and fingerprints) the whole
        // group; names and fingerprint are prebuilt shared handles on
        // the bundle, so stamping them on every row of the dispatch is
        // refcount traffic, not string allocation.
        let bundle = variant.slot.get();
        let dim = bundle.n_inputs();
        let fingerprint = bundle.fingerprint_shared();
        let names = bundle.design_names();

        let (mut ok_jobs, bad_jobs): (Vec<Job>, Vec<Job>) =
            jobs.into_iter().partition(|j| j.input.len() == dim);
        for job in bad_jobs {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            let msg = format!(
                "input has {} values but '{}' takes {} ({})",
                job.input.len(),
                variant.name,
                dim,
                bundle.input_space().names().join(", ")
            );
            let _ = job.reply.send(Err(msg));
        }
        if ok_jobs.is_empty() {
            continue;
        }

        // Observe every valid row into the variant's reservoir (the
        // closed loop's input) while the inputs are still intact — the
        // multi-row dispatch below moves them out. Records come only
        // from this single batcher thread, so per-variant observation
        // order is flush order: deterministic for sequential traffic.
        for job in &ok_jobs {
            variant.samples.record(&job.input);
        }

        let n = ok_jobs.len();
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.batched_rows.fetch_add(n as u64, Ordering::Relaxed);
        stats.window.record(0, 1, n as u64, 0);
        let configs: Vec<Vec<f64>> = if n == 1 {
            // Lone rows take the memoized scalar path: identical result,
            // and repeated hot shapes hit the input cache.
            vec![bundle.decide(&ok_jobs[0].input)]
        } else {
            // Inputs are never needed after dispatch — move them out
            // instead of cloning every row.
            let rows: Vec<Vec<f64>> =
                ok_jobs.iter_mut().map(|j| std::mem::take(&mut j.input)).collect();
            bundle.decide_batch(&rows, threads)
        };
        for (job, values) in ok_jobs.into_iter().zip(configs) {
            let _ = job.reply.send(Ok(DecideOk {
                names: names.clone(),
                values,
                fingerprint: fingerprint.clone(),
                batch: n,
            }));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::space::{ParamDef, ParamSpace};
    use crate::dtree::DesignTrees;
    use crate::runtime::serving::TreeBundle;
    use crate::runtime::server::reload::ReloadableBundle;
    use crate::runtime::server::VariantStats;
    use std::sync::mpsc::sync_channel;

    fn variant() -> Arc<ServedVariant> {
        let input = ParamSpace::new(vec![
            ParamDef::float("n", 1.0, 100.0),
            ParamDef::float("m", 1.0, 100.0),
        ]);
        let design = ParamSpace::new(vec![ParamDef::int("threads", 1, 64)]);
        let inputs = input.grid(8);
        let designs: Vec<Vec<f64>> =
            inputs.iter().map(|p| vec![if p[0] < 50.0 { 4.0 } else { 32.0 }]).collect();
        let trees = DesignTrees::fit(&inputs, &designs, &input, &design, 4);
        Arc::new(ServedVariant {
            kernel: "toy".into(),
            profile: None,
            name: "toy".into(),
            slot: ReloadableBundle::new(TreeBundle::from_trees(trees).unwrap(), None),
            stats: VariantStats::default(),
            samples: Arc::new(crate::runtime::server::reservoir::Reservoir::for_variant(
                "toy", 64,
            )),
        })
    }

    fn job(v: &Arc<ServedVariant>, input: Vec<f64>) -> (Job, std::sync::mpsc::Receiver<Outcome>) {
        let (tx, rx) = sync_channel(1);
        (
            Job { variant: v.clone(), input, enqueued: Instant::now(), reply: tx },
            rx,
        )
    }

    #[test]
    fn flush_answers_every_job_bit_identically() {
        let v = variant();
        let bundle = v.slot.get();
        let inputs: Vec<Vec<f64>> =
            (0..7).map(|i| vec![10.0 + 11.0 * i as f64, 90.0 - 9.0 * i as f64]).collect();
        let mut rxs = Vec::new();
        let mut jobs = Vec::new();
        for q in &inputs {
            let (j, rx) = job(&v, q.clone());
            jobs.push(j);
            rxs.push(rx);
        }
        flush(jobs, 1);
        for (q, rx) in inputs.iter().zip(rxs) {
            let ok = rx.recv().unwrap().unwrap();
            assert_eq!(ok.values, bundle.decide(q), "{q:?}");
            assert_eq!(ok.batch, 7);
            assert_eq!(ok.names.as_ref(), &["threads".to_string()][..]);
        }
        assert_eq!(v.stats.requests.load(Ordering::Relaxed), 7);
        assert_eq!(v.stats.batches.load(Ordering::Relaxed), 1);
        assert_eq!(v.stats.batched_rows.load(Ordering::Relaxed), 7);
        assert!((v.stats.mean_batch() - 7.0).abs() < 1e-12);
    }

    #[test]
    fn flush_records_served_rows_into_reservoir_and_window() {
        let v = variant();
        let inputs: Vec<Vec<f64>> =
            (0..5).map(|i| vec![2.0 * i as f64 + 1.0, 3.0 + i as f64]).collect();
        let mut jobs = Vec::new();
        let mut rxs = Vec::new();
        for q in &inputs {
            let (j, rx) = job(&v, q.clone());
            jobs.push(j);
            rxs.push(rx);
        }
        // A bad-dimension job must be answered but never observed.
        let (bad, bad_rx) = job(&v, vec![1.0]);
        jobs.push(bad);
        flush(jobs, 1);
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        assert!(bad_rx.recv().unwrap().is_err());
        assert_eq!(v.samples.seen(), 5, "only valid rows are observed");
        // Below capacity the reservoir is exactly the served stream,
        // in flush order (inputs were recorded before the batch path
        // took them).
        assert_eq!(v.samples.snapshot(None).1, inputs);
        // The window saw the same flush once; snapshotting resets it.
        let w = v.stats.window.snapshot_and_reset();
        assert_eq!((w.requests, w.batches, w.rows), (6, 1, 5));
        assert_eq!(v.stats.window.snapshot_and_reset().requests, 0);
    }

    #[test]
    fn flush_rejects_bad_dimensions_without_poisoning_the_batch() {
        let v = variant();
        let (good, good_rx) = job(&v, vec![20.0, 30.0]);
        let (bad, bad_rx) = job(&v, vec![20.0]);
        flush(vec![good, bad], 1);
        assert!(good_rx.recv().unwrap().is_ok());
        let err = bad_rx.recv().unwrap().unwrap_err();
        assert!(err.contains("takes 2"), "{err}");
        assert_eq!(v.stats.errors.load(Ordering::Relaxed), 1);
        // The valid row still counted as a (singleton) dispatch.
        assert_eq!(v.stats.batches.load(Ordering::Relaxed), 1);
        assert_eq!(v.stats.batched_rows.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn queue_batches_up_to_the_size_cap_and_drains_on_shutdown() {
        let v = variant();
        let queue = BatchQueue::new(64);
        let mut rxs = Vec::new();
        for i in 0..10 {
            let (j, rx) = job(&v, vec![5.0 + i as f64, 50.0]);
            queue.push(j).unwrap();
            rxs.push(rx);
        }
        // Run the batcher with a size cap of 4: 10 queued jobs must
        // produce dispatches of at most 4 rows and answer everything.
        let q = queue.clone();
        let handle = std::thread::spawn(move || {
            q.run(4, Duration::from_micros(50), 1);
        });
        for rx in rxs {
            let ok = rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
            assert!(ok.batch <= 4, "batch {} exceeded the size cap", ok.batch);
        }
        queue.shutdown();
        handle.join().unwrap();
        assert_eq!(v.stats.requests.load(Ordering::Relaxed), 10);
        assert!(v.stats.batches.load(Ordering::Relaxed) >= 3);
        // Push after shutdown errors instead of hanging.
        let (j, _rx) = job(&v, vec![1.0, 1.0]);
        assert!(queue.push(j).is_err());
    }
}
