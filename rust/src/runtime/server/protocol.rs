//! Wire protocol for `mlkaps served` (reference: `docs/protocol.md`).
//!
//! One protocol, two framings, auto-detected per connection from its
//! first byte:
//!
//! * **Binary** — each message is a 4-byte big-endian length prefix
//!   followed by that many bytes of UTF-8 JSON. Frames are capped at
//!   [`MAX_FRAME`] (16 MiB), so the first byte of a well-formed binary
//!   connection is always `0x00` — that is the detection rule. This is
//!   the framing the Rust [`super::client::ServedClient`] speaks and
//!   what a C/Fortran shim should implement (a length prefix needs no
//!   incremental JSON parser on either side).
//! * **Text** — newline-delimited: one request per line (a JSON object,
//!   or a bare verb like `STATS`), one JSON response per line. Any
//!   first byte other than `0x00` selects text mode, so
//!   `printf '...\n' | nc` works from a shell with zero tooling.
//!
//! Requests are either a **decide** (`{"kernel": ..., "input": [...]}`
//! with optional `"profile"` and opaque `"id"`) or an **op**
//! (`{"op": "stats"}` / bare `STATS` in text mode). Responses always
//! carry `"ok"`; decide responses carry the chosen config both as a
//! named object (`"config"`) and as the raw value-space array
//! (`"values"`, the bit-exact payload in design-space order).
//!
//! `PING` doubles as the health probe: its response carries a
//! `"fingerprints"` object mapping every registered variant to the run
//! fingerprint it currently serves, which is how the `mlkaps fleet`
//! supervisor distinguishes "alive" from "alive *and* serving the new
//! epoch" during a rolling redeploy (see `docs/protocol.md`).
//!
//! JSON numbers are f64 and the serializer emits shortest
//! round-tripping decimal forms, so finite values survive the wire
//! bit-exactly. NaN/Inf are **not** representable in a request input
//! (JSON has no literal for them); the daemon rejects such rows rather
//! than guessing.

use std::io::{Read, Write};

use crate::util::json::{self, Value};

/// Upper bound on one frame's payload (16 MiB). Also the framing
/// detection invariant: lengths below 2^24 make the first prefix byte
/// 0x00, which no text-mode request can start with.
pub const MAX_FRAME: usize = 16 << 20;

/// Why a frame operation failed, classified so the daemon can count
/// socket timeouts and malformed frames separately, and answer an
/// oversized length announcement with an error response instead of a
/// bare disconnect. `Display` renders the human-readable message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// The peer announced (or the caller built) a frame larger than
    /// [`MAX_FRAME`]. Detected from the 4 prefix bytes alone — the
    /// absurd allocation is never attempted.
    Oversized(usize),
    /// The socket's read/write timeout elapsed mid-frame.
    TimedOut,
    /// Any other I/O failure (peer reset, truncated payload, …).
    Io(String),
}

impl FrameError {
    fn from_io(e: std::io::Error, what: &str) -> FrameError {
        match e.kind() {
            // Unix read/write timeouts surface as WouldBlock; some
            // platforms report TimedOut.
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                FrameError::TimedOut
            }
            _ => FrameError::Io(format!("{what}: {e}")),
        }
    }
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Oversized(len) => {
                write!(f, "frame of {len} bytes exceeds the {MAX_FRAME}-byte cap")
            }
            FrameError::TimedOut => write!(f, "socket timed out mid-frame"),
            FrameError::Io(msg) => f.write_str(msg),
        }
    }
}

/// Write one length-prefixed frame.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<(), FrameError> {
    if payload.len() >= MAX_FRAME {
        return Err(FrameError::Oversized(payload.len()));
    }
    let len = (payload.len() as u32).to_be_bytes();
    w.write_all(&len).map_err(|e| FrameError::from_io(e, "write frame length"))?;
    w.write_all(payload).map_err(|e| FrameError::from_io(e, "write frame payload"))?;
    w.flush().map_err(|e| FrameError::from_io(e, "flush frame"))
}

/// Read one length-prefixed frame. `Ok(None)` on clean EOF at a frame
/// boundary (the peer hung up between requests). A length prefix at or
/// above [`MAX_FRAME`] is rejected as [`FrameError::Oversized`] before
/// any payload buffer is allocated.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>, FrameError> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(FrameError::from_io(e, "read frame length")),
    }
    let len = u32::from_be_bytes(len_buf) as usize;
    if len >= MAX_FRAME {
        return Err(FrameError::Oversized(len));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).map_err(|e| FrameError::from_io(e, "read frame payload"))?;
    Ok(Some(buf))
}

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Which config for this input? `profile` overrides the daemon's
    /// default hardware-profile variant; `id` is echoed back opaquely.
    Decide {
        kernel: String,
        input: Vec<f64>,
        profile: Option<String>,
        id: Option<Value>,
    },
    /// Telemetry snapshot (per-variant counters + daemon globals).
    Stats,
    /// Observed-input reservoir dump: the rows `mlkaps retune` pulls.
    /// `kernel` restricts to one variant (all when `None`); `limit`
    /// caps the rows returned per variant (all resident when `None`).
    Samples { kernel: Option<String>, limit: Option<usize> },
    /// Registered bundle variants with fingerprints.
    List,
    /// Liveness probe.
    Ping,
    /// Poll every watched checkpoint directory now (don't wait for the
    /// reload thread's next tick).
    Reload,
    /// Rolling-restart drain: stop accepting new connections, answer
    /// every request already read off a socket, then exit 0. Unlike
    /// `Shutdown`, requests in flight on other connections are served,
    /// not error-answered.
    Drain,
    /// Stop accepting connections and exit the daemon.
    Shutdown,
}

impl Request {
    /// The bare text-mode verbs (case-insensitive).
    pub fn from_verb(verb: &str) -> Option<Request> {
        match verb.to_ascii_lowercase().as_str() {
            "stats" => Some(Request::Stats),
            "samples" => Some(Request::Samples { kernel: None, limit: None }),
            "list" => Some(Request::List),
            "ping" => Some(Request::Ping),
            "reload" => Some(Request::Reload),
            "drain" => Some(Request::Drain),
            "shutdown" => Some(Request::Shutdown),
            _ => None,
        }
    }

    /// Parse a JSON request object (either framing).
    pub fn from_json(v: &Value) -> Result<Request, String> {
        if let Some(op) = v.get("op").and_then(|o| o.as_str()) {
            // `samples` takes optional arguments, which the bare-verb
            // table can't carry — intercept it before the generic route.
            if op.eq_ignore_ascii_case("samples") {
                let kernel = match v.get("kernel") {
                    None | Some(Value::Null) => None,
                    Some(k) => Some(
                        k.as_str().ok_or("\"kernel\" must be a string")?.to_string(),
                    ),
                };
                let limit = match v.get("limit") {
                    None | Some(Value::Null) => None,
                    Some(l) => {
                        // `as_usize` saturates (-1 → 0); validate the
                        // literal before converting.
                        let f = l
                            .as_f64()
                            .ok_or("\"limit\" must be a non-negative integer")?;
                        if !(f.is_finite() && f >= 0.0 && f.fract() == 0.0) {
                            return Err(
                                "\"limit\" must be a non-negative integer".into()
                            );
                        }
                        Some(f as usize)
                    }
                };
                return Ok(Request::Samples { kernel, limit });
            }
            return Request::from_verb(op).ok_or_else(|| {
                format!(
                    "unknown op '{op}' (stats, samples, list, ping, reload, drain, \
                     shutdown)"
                )
            });
        }
        let kernel = v
            .get("kernel")
            .and_then(|k| k.as_str())
            .ok_or("request needs \"kernel\" (or an \"op\")")?
            .to_string();
        let input = v
            .get("input")
            .and_then(|a| a.as_arr())
            .ok_or("request needs \"input\": [numbers]")?
            .iter()
            // `filter` catches overflow literals like 1e999, which the
            // JSON parser turns into f64 infinity.
            .map(|x| {
                x.as_f64()
                    .filter(|v| v.is_finite())
                    .ok_or("\"input\" entries must be finite numbers")
            })
            .collect::<Result<Vec<f64>, &str>>()
            .map_err(str::to_string)?;
        let profile = match v.get("profile") {
            None | Some(Value::Null) => None,
            Some(p) => Some(
                p.as_str()
                    .ok_or("\"profile\" must be a string")?
                    .to_string(),
            ),
        };
        Ok(Request::Decide { kernel, input, profile, id: v.get("id").cloned() })
    }

    /// Parse one text-mode line: a bare verb or a JSON object.
    pub fn from_line(line: &str) -> Result<Request, String> {
        let line = line.trim();
        if line.is_empty() {
            return Err("empty request line".into());
        }
        if line.starts_with('{') {
            let v = json::parse(line)?;
            Request::from_json(&v)
        } else {
            Request::from_verb(line)
                .ok_or_else(|| format!("unknown verb '{line}' (or send a JSON object)"))
        }
    }

    /// Serialize for the wire (what [`super::client::ServedClient`]
    /// sends; the daemon's parser is the inverse).
    pub fn to_json(&self) -> Value {
        match self {
            Request::Decide { kernel, input, profile, id } => {
                let mut pairs = vec![
                    ("kernel", Value::Str(kernel.clone())),
                    (
                        "input",
                        Value::Arr(input.iter().map(|&v| Value::Num(v)).collect()),
                    ),
                ];
                if let Some(p) = profile {
                    pairs.push(("profile", Value::Str(p.clone())));
                }
                if let Some(id) = id {
                    pairs.push(("id", id.clone()));
                }
                Value::obj(pairs)
            }
            Request::Stats => Value::obj(vec![("op", Value::Str("stats".into()))]),
            Request::Samples { kernel, limit } => {
                let mut pairs = vec![("op", Value::Str("samples".into()))];
                if let Some(k) = kernel {
                    pairs.push(("kernel", Value::Str(k.clone())));
                }
                if let Some(l) = limit {
                    pairs.push(("limit", Value::Num(*l as f64)));
                }
                Value::obj(pairs)
            }
            Request::List => Value::obj(vec![("op", Value::Str("list".into()))]),
            Request::Ping => Value::obj(vec![("op", Value::Str("ping".into()))]),
            Request::Reload => Value::obj(vec![("op", Value::Str("reload".into()))]),
            Request::Drain => Value::obj(vec![("op", Value::Str("drain".into()))]),
            Request::Shutdown => Value::obj(vec![("op", Value::Str("shutdown".into()))]),
        }
    }
}

/// Build an error response, echoing the request id when present.
pub fn err_response(msg: &str, id: Option<&Value>) -> Value {
    let mut pairs =
        vec![("ok", Value::Bool(false)), ("error", Value::Str(msg.to_string()))];
    if let Some(id) = id {
        pairs.push(("id", id.clone()));
    }
    Value::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"{\"op\":\"ping\"}").unwrap();
        write_frame(&mut buf, b"").unwrap();
        assert_eq!(buf[0], 0x00, "framing detection byte must be 0x00");
        let mut r = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"{\"op\":\"ping\"}");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"");
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF is None");
    }

    #[test]
    fn oversized_frames_are_rejected_both_ways() {
        let mut buf = Vec::new();
        assert_eq!(
            write_frame(&mut buf, &vec![0u8; MAX_FRAME]),
            Err(FrameError::Oversized(MAX_FRAME))
        );
        let mut r = std::io::Cursor::new(u32::MAX.to_be_bytes().to_vec());
        assert_eq!(read_frame(&mut r), Err(FrameError::Oversized(u32::MAX as usize)));
        let mut r = std::io::Cursor::new((MAX_FRAME as u32).to_be_bytes().to_vec());
        assert!(matches!(read_frame(&mut r), Err(FrameError::Oversized(_))));
    }

    #[test]
    fn truncated_frame_is_an_error_not_a_hang() {
        let mut buf = (100u32).to_be_bytes().to_vec();
        buf.extend_from_slice(b"short");
        let mut r = std::io::Cursor::new(buf);
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn decide_requests_roundtrip_through_json() {
        let req = Request::Decide {
            kernel: "dgetrf".into(),
            input: vec![4500.0, 1600.5],
            profile: Some("spr".into()),
            id: Some(Value::Num(7.0)),
        };
        let text = req.to_json().to_string();
        assert_eq!(Request::from_line(&text).unwrap(), req);

        let bare = Request::Decide {
            kernel: "toy".into(),
            input: vec![1.0],
            profile: None,
            id: None,
        };
        assert_eq!(
            Request::from_json(&json::parse(&bare.to_json().to_string()).unwrap()).unwrap(),
            bare
        );
    }

    #[test]
    fn verbs_parse_in_both_modes() {
        assert_eq!(Request::from_line("STATS").unwrap(), Request::Stats);
        assert_eq!(Request::from_line("  ping  ").unwrap(), Request::Ping);
        assert_eq!(Request::from_line("{\"op\":\"reload\"}").unwrap(), Request::Reload);
        assert_eq!(Request::from_line("DRAIN").unwrap(), Request::Drain);
        assert_eq!(Request::from_line("{\"op\":\"drain\"}").unwrap(), Request::Drain);
        assert_eq!(Request::from_line("{\"op\":\"shutdown\"}").unwrap(), Request::Shutdown);
        assert_eq!(Request::from_line("{\"op\":\"list\"}").unwrap(), Request::List);
        assert!(Request::from_line("EXPLODE").is_err());
        assert!(Request::from_line("").is_err());
    }

    #[test]
    fn samples_requests_parse_in_both_modes_and_roundtrip() {
        // Bare verb: everything, every variant.
        assert_eq!(
            Request::from_line("SAMPLES").unwrap(),
            Request::Samples { kernel: None, limit: None }
        );
        // JSON op with arguments, both framings share this parser.
        assert_eq!(
            Request::from_line("{\"op\":\"samples\",\"kernel\":\"toy\",\"limit\":16}")
                .unwrap(),
            Request::Samples { kernel: Some("toy".into()), limit: Some(16) }
        );
        assert_eq!(
            Request::from_line("{\"op\":\"samples\",\"kernel\":null}").unwrap(),
            Request::Samples { kernel: None, limit: None }
        );
        for req in [
            Request::Samples { kernel: None, limit: None },
            Request::Samples { kernel: Some("toy@spr".into()), limit: Some(3) },
        ] {
            assert_eq!(Request::from_line(&req.to_json().to_string()).unwrap(), req);
        }
        // Bad arguments are rejected, and the op list names samples.
        assert!(Request::from_line("{\"op\":\"samples\",\"limit\":-1}").is_err());
        assert!(Request::from_line("{\"op\":\"samples\",\"kernel\":7}").is_err());
        let err = Request::from_line("{\"op\":\"nope\"}").unwrap_err();
        assert!(err.contains("samples"), "{err}");
    }

    #[test]
    fn malformed_decides_are_rejected() {
        assert!(Request::from_line("{\"input\":[1]}").is_err(), "missing kernel");
        assert!(Request::from_line("{\"kernel\":\"x\"}").is_err(), "missing input");
        assert!(
            Request::from_line("{\"kernel\":\"x\",\"input\":[null]}").is_err(),
            "non-numeric input entry (e.g. a NaN serialized to null)"
        );
        assert!(
            Request::from_line("{\"kernel\":\"x\",\"input\":[1e999]}").is_err(),
            "overflow literal parses to infinity and must be rejected"
        );
        assert!(
            Request::from_line("{\"kernel\":\"x\",\"input\":[1],\"profile\":3}").is_err(),
            "non-string profile"
        );
    }

    #[test]
    fn error_responses_echo_the_id() {
        let id = Value::Str("req-9".into());
        let v = err_response("boom", Some(&id));
        assert_eq!(v.get("ok").and_then(Value::as_bool), Some(false));
        assert_eq!(v.get("error").and_then(Value::as_str), Some("boom"));
        assert_eq!(v.get("id"), Some(&id));
        assert!(err_response("x", None).get("id").is_none());
    }
}
