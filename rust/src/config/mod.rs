//! Experiment configuration: parameter-space descriptions and the
//! constrained-parameter reformulation (Table 1 of the paper).

pub mod space;

pub use space::{lerp, ParamDef, ParamKind, ParamSpace};
