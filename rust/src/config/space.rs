//! Parameter-space description.
//!
//! Samplers and optimizers operate in the **unit cube** [0,1]^d; the space
//! maps unit coordinates to **value space** (the numbers the kernel sees):
//! floats lerp (optionally log-scaled), ints round, categoricals index
//! their choice list, bools threshold at 0.5. Surrogates and decision
//! trees consume value-space features directly.
//!
//! [`lerp`] is also the paper's Table 1 reformulation primitive: a
//! constrained parameter `mb ∈ [1, m/8p]` becomes a free α ∈ [0,1] with
//! `mb = lerp(α, 1, m/8p)` — implemented verbatim by the pdgeqrf kernel.

use crate::util::json::Value;

/// Linear interpolation between `lo` and `hi` with t ∈ [0,1] (clamped).
pub fn lerp(t: f64, lo: f64, hi: f64) -> f64 {
    lo + (hi - lo) * t.clamp(0.0, 1.0)
}

/// The type and domain of a single parameter.
#[derive(Clone, Debug, PartialEq)]
pub enum ParamKind {
    /// Continuous in [lo, hi]; `log` uses a log-uniform mapping.
    Float { lo: f64, hi: f64, log: bool },
    /// Integer in [lo, hi] inclusive.
    Int { lo: i64, hi: i64 },
    /// One of a fixed list of choices (encoded by index in value space).
    Categorical { choices: Vec<String> },
    /// Boolean (encoded 0.0 / 1.0 in value space).
    Bool,
}

/// A named parameter.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamDef {
    pub name: String,
    pub kind: ParamKind,
}

impl ParamDef {
    pub fn float(name: &str, lo: f64, hi: f64) -> Self {
        assert!(lo < hi, "{name}: empty float range");
        ParamDef { name: name.into(), kind: ParamKind::Float { lo, hi, log: false } }
    }
    pub fn log_float(name: &str, lo: f64, hi: f64) -> Self {
        assert!(0.0 < lo && lo < hi, "{name}: log range needs 0 < lo < hi");
        ParamDef { name: name.into(), kind: ParamKind::Float { lo, hi, log: true } }
    }
    pub fn int(name: &str, lo: i64, hi: i64) -> Self {
        assert!(lo <= hi, "{name}: empty int range");
        ParamDef { name: name.into(), kind: ParamKind::Int { lo, hi } }
    }
    pub fn categorical(name: &str, choices: &[&str]) -> Self {
        assert!(!choices.is_empty(), "{name}: no choices");
        ParamDef {
            name: name.into(),
            kind: ParamKind::Categorical {
                choices: choices.iter().map(|s| s.to_string()).collect(),
            },
        }
    }
    pub fn boolean(name: &str) -> Self {
        ParamDef { name: name.into(), kind: ParamKind::Bool }
    }

    /// Map a unit coordinate to value space.
    pub fn decode(&self, u: f64) -> f64 {
        let u = u.clamp(0.0, 1.0);
        match &self.kind {
            ParamKind::Float { lo, hi, log: false } => lerp(u, *lo, *hi),
            ParamKind::Float { lo, hi, log: true } => {
                (lerp(u, lo.ln(), hi.ln())).exp()
            }
            ParamKind::Int { lo, hi } => {
                let n = (hi - lo + 1) as f64;
                (*lo + ((u * n).floor() as i64).min(hi - lo)) as f64
            }
            ParamKind::Categorical { choices } => {
                let n = choices.len() as f64;
                ((u * n).floor()).min(n - 1.0)
            }
            ParamKind::Bool => {
                if u < 0.5 {
                    0.0
                } else {
                    1.0
                }
            }
        }
    }

    /// Map a value back to the center of its unit-space preimage.
    pub fn encode(&self, v: f64) -> f64 {
        match &self.kind {
            ParamKind::Float { lo, hi, log: false } => ((v - lo) / (hi - lo)).clamp(0.0, 1.0),
            ParamKind::Float { lo, hi, log: true } => {
                ((v.max(*lo).ln() - lo.ln()) / (hi.ln() - lo.ln())).clamp(0.0, 1.0)
            }
            ParamKind::Int { lo, hi } => {
                let n = (hi - lo + 1) as f64;
                ((v - *lo as f64 + 0.5) / n).clamp(0.0, 1.0)
            }
            ParamKind::Categorical { choices } => {
                let n = choices.len() as f64;
                ((v + 0.5) / n).clamp(0.0, 1.0)
            }
            ParamKind::Bool => {
                if v < 0.5 {
                    0.25
                } else {
                    0.75
                }
            }
        }
    }

    /// Snap an arbitrary value-space number to the nearest valid value.
    pub fn snap(&self, v: f64) -> f64 {
        match &self.kind {
            ParamKind::Float { lo, hi, .. } => v.clamp(*lo, *hi),
            ParamKind::Int { lo, hi } => (v.round() as i64).clamp(*lo, *hi) as f64,
            ParamKind::Categorical { choices } => {
                (v.round() as i64).clamp(0, choices.len() as i64 - 1) as f64
            }
            ParamKind::Bool => {
                if v < 0.5 {
                    0.0
                } else {
                    1.0
                }
            }
        }
    }

    /// Number of distinct values (None = continuous).
    pub fn cardinality(&self) -> Option<u64> {
        match &self.kind {
            ParamKind::Float { .. } => None,
            ParamKind::Int { lo, hi } => Some((hi - lo + 1) as u64),
            ParamKind::Categorical { choices } => Some(choices.len() as u64),
            ParamKind::Bool => Some(2),
        }
    }

    /// Is this a categorical/bool feature (unordered) for the surrogate?
    pub fn is_unordered(&self) -> bool {
        matches!(self.kind, ParamKind::Categorical { .. } | ParamKind::Bool)
    }

    /// Value-space bounds (lo, hi) of the encoded representation.
    pub fn bounds(&self) -> (f64, f64) {
        match &self.kind {
            ParamKind::Float { lo, hi, .. } => (*lo, *hi),
            ParamKind::Int { lo, hi } => (*lo as f64, *hi as f64),
            ParamKind::Categorical { choices } => (0.0, choices.len() as f64 - 1.0),
            ParamKind::Bool => (0.0, 1.0),
        }
    }
}

/// An ordered collection of parameters: the input space, the design space,
/// or their concatenation (the sampling space).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ParamSpace {
    pub params: Vec<ParamDef>,
}

impl ParamSpace {
    pub fn new(params: Vec<ParamDef>) -> Self {
        ParamSpace { params }
    }

    pub fn dim(&self) -> usize {
        self.params.len()
    }

    pub fn names(&self) -> Vec<&str> {
        self.params.iter().map(|p| p.name.as_str()).collect()
    }

    /// Concatenate two spaces (input ⊗ design = joint sampling space).
    pub fn concat(&self, other: &ParamSpace) -> ParamSpace {
        let mut params = self.params.clone();
        params.extend(other.params.iter().cloned());
        ParamSpace { params }
    }

    /// Decode a unit-cube point to value space.
    pub fn decode(&self, unit: &[f64]) -> Vec<f64> {
        assert_eq!(unit.len(), self.dim(), "dim mismatch");
        self.params.iter().zip(unit).map(|(p, &u)| p.decode(u)).collect()
    }

    /// Encode a value-space point back into the unit cube.
    pub fn encode(&self, value: &[f64]) -> Vec<f64> {
        assert_eq!(value.len(), self.dim(), "dim mismatch");
        self.params.iter().zip(value).map(|(p, &v)| p.encode(v)).collect()
    }

    /// Snap a value-space point onto valid values.
    pub fn snap(&self, value: &[f64]) -> Vec<f64> {
        assert_eq!(value.len(), self.dim(), "dim mismatch");
        self.params.iter().zip(value).map(|(p, &v)| p.snap(v)).collect()
    }

    /// Total number of discrete configurations; `None` if any parameter is
    /// continuous. The paper quotes 4.6e13 for dgetrf's design space.
    pub fn cardinality(&self) -> Option<f64> {
        let mut total = 1.0f64;
        for p in &self.params {
            total *= p.cardinality()? as f64;
        }
        Some(total)
    }

    /// Regular grid with `per_dim` points per dimension, in value space.
    /// (The paper's optimization grid: 16x16 by default; validation 46x46.)
    pub fn grid(&self, per_dim: usize) -> Vec<Vec<f64>> {
        assert!(per_dim >= 1);
        let d = self.dim();
        let mut out = Vec::with_capacity(per_dim.pow(d as u32));
        let mut idx = vec![0usize; d];
        loop {
            let unit: Vec<f64> = idx
                .iter()
                .map(|&i| {
                    if per_dim == 1 {
                        0.5
                    } else {
                        i as f64 / (per_dim - 1) as f64
                    }
                })
                .collect();
            out.push(self.decode(&unit));
            // odometer increment
            let mut k = 0;
            loop {
                idx[k] += 1;
                if idx[k] < per_dim {
                    break;
                }
                idx[k] = 0;
                k += 1;
                if k == d {
                    return out;
                }
            }
        }
    }

    /// Flags marking unordered (categorical/bool) dimensions for the GBDT.
    pub fn unordered_mask(&self) -> Vec<bool> {
        self.params.iter().map(|p| p.is_unordered()).collect()
    }

    /// Value-space bounds per dimension.
    pub fn bounds(&self) -> Vec<(f64, f64)> {
        self.params.iter().map(|p| p.bounds()).collect()
    }

    /// Parse a space serialized with [`ParamSpace::to_json`]. Malformed
    /// documents return `Err` (never panic) so checkpoint loaders can fall
    /// back to recomputation.
    pub fn from_json(v: &Value) -> Result<ParamSpace, String> {
        let arr = v.as_arr().ok_or("space must be an array")?;
        let params = arr
            .iter()
            .map(|p| -> Result<ParamDef, String> {
                let name = p.get("name").and_then(|n| n.as_str()).ok_or("no name")?;
                let kind = match p.get("kind").and_then(|k| k.as_str()) {
                    Some("float") => {
                        let lo = p.get("lo").and_then(|x| x.as_f64()).ok_or("no lo")?;
                        let hi = p.get("hi").and_then(|x| x.as_f64()).ok_or("no hi")?;
                        if lo.is_nan() || hi.is_nan() || lo >= hi {
                            return Err(format!("{name}: empty float range"));
                        }
                        let log = p.get("log").and_then(|x| x.as_bool()).unwrap_or(false);
                        if log && lo <= 0.0 {
                            return Err(format!("{name}: log range needs lo > 0"));
                        }
                        ParamKind::Float { lo, hi, log }
                    }
                    Some("int") => {
                        let lo = p.get("lo").and_then(|x| x.as_f64()).ok_or("no lo")? as i64;
                        let hi = p.get("hi").and_then(|x| x.as_f64()).ok_or("no hi")? as i64;
                        if lo > hi {
                            return Err(format!("{name}: empty int range"));
                        }
                        ParamKind::Int { lo, hi }
                    }
                    Some("categorical") => {
                        let choices: Vec<String> = p
                            .get("choices")
                            .and_then(|c| c.as_arr())
                            .ok_or("no choices")?
                            .iter()
                            .map(|c| c.as_str().map(str::to_string).ok_or("bad choice"))
                            .collect::<Result<_, _>>()?;
                        if choices.is_empty() {
                            return Err(format!("{name}: no choices"));
                        }
                        ParamKind::Categorical { choices }
                    }
                    Some("bool") => ParamKind::Bool,
                    other => return Err(format!("unknown kind {other:?}")),
                };
                Ok(ParamDef { name: name.to_string(), kind })
            })
            .collect::<Result<Vec<_>, _>>()?;
        Ok(ParamSpace::new(params))
    }

    /// Serialize the space description to JSON (for experiment records).
    pub fn to_json(&self) -> Value {
        Value::Arr(
            self.params
                .iter()
                .map(|p| {
                    let (kind, extra) = match &p.kind {
                        ParamKind::Float { lo, hi, log } => (
                            "float",
                            vec![
                                ("lo", Value::Num(*lo)),
                                ("hi", Value::Num(*hi)),
                                ("log", Value::Bool(*log)),
                            ],
                        ),
                        ParamKind::Int { lo, hi } => (
                            "int",
                            vec![
                                ("lo", Value::Num(*lo as f64)),
                                ("hi", Value::Num(*hi as f64)),
                            ],
                        ),
                        ParamKind::Categorical { choices } => (
                            "categorical",
                            vec![(
                                "choices",
                                Value::Arr(
                                    choices.iter().map(|c| Value::Str(c.clone())).collect(),
                                ),
                            )],
                        ),
                        ParamKind::Bool => ("bool", vec![]),
                    };
                    let mut fields = vec![
                        ("name", Value::Str(p.name.clone())),
                        ("kind", Value::Str(kind.into())),
                    ];
                    fields.extend(extra);
                    Value::obj(fields)
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn space() -> ParamSpace {
        ParamSpace::new(vec![
            ParamDef::float("x", -2.0, 2.0),
            ParamDef::int("threads", 1, 64),
            ParamDef::categorical("variant", &["a", "b", "c"]),
            ParamDef::boolean("flag"),
            ParamDef::log_float("tol", 1e-6, 1.0),
        ])
    }

    #[test]
    fn decode_endpoints() {
        let s = space();
        let lo = s.decode(&[0.0, 0.0, 0.0, 0.0, 0.0]);
        let hi = s.decode(&[1.0, 1.0, 1.0, 1.0, 1.0]);
        assert_eq!(&lo[..4], &[-2.0, 1.0, 0.0, 0.0]);
        assert!((lo[4] - 1e-6).abs() < 1e-12);
        assert_eq!(hi[0], 2.0);
        assert_eq!(hi[1], 64.0);
        assert_eq!(hi[2], 2.0);
        assert_eq!(hi[3], 1.0);
        assert!((hi[4] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn int_decode_is_uniform() {
        let p = ParamDef::int("t", 1, 4);
        let mut counts = [0; 4];
        for i in 0..1000 {
            let u = i as f64 / 1000.0;
            counts[(p.decode(u) as usize) - 1] += 1;
        }
        for c in counts {
            assert_eq!(c, 250);
        }
    }

    #[test]
    fn encode_decode_roundtrip_discrete() {
        let s = space();
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let unit: Vec<f64> = (0..s.dim()).map(|_| rng.f64()).collect();
            let v = s.decode(&unit);
            let v2 = s.decode(&s.encode(&v));
            assert_eq!(v, v2, "decode∘encode must be idempotent on values");
        }
    }

    #[test]
    fn snap_clamps_and_rounds() {
        let s = space();
        let v = s.snap(&[5.0, 3.7, 9.0, 0.2, 2.0]);
        assert_eq!(v, vec![2.0, 4.0, 2.0, 0.0, 1.0]);
    }

    #[test]
    fn log_float_midpoint_is_geometric() {
        let p = ParamDef::log_float("tol", 1e-4, 1.0);
        assert!((p.decode(0.5) - 1e-2).abs() < 1e-9);
    }

    #[test]
    fn cardinality() {
        let s = ParamSpace::new(vec![
            ParamDef::int("a", 1, 10),
            ParamDef::categorical("b", &["x", "y"]),
            ParamDef::boolean("c"),
        ]);
        assert_eq!(s.cardinality(), Some(40.0));
        assert_eq!(space().cardinality(), None); // has floats
    }

    #[test]
    fn grid_shape_and_coverage() {
        let s = ParamSpace::new(vec![
            ParamDef::float("x", 0.0, 1.0),
            ParamDef::float("y", 0.0, 10.0),
        ]);
        let g = s.grid(4);
        assert_eq!(g.len(), 16);
        assert!(g.contains(&vec![0.0, 0.0]));
        assert!(g.contains(&vec![1.0, 10.0]));
        let g1 = s.grid(1);
        assert_eq!(g1, vec![vec![0.5, 5.0]]);
    }

    #[test]
    fn concat_spaces() {
        let a = ParamSpace::new(vec![ParamDef::float("m", 0.0, 1.0)]);
        let b = ParamSpace::new(vec![ParamDef::int("t", 1, 2)]);
        let j = a.concat(&b);
        assert_eq!(j.dim(), 2);
        assert_eq!(j.names(), vec!["m", "t"]);
    }

    #[test]
    fn unordered_mask() {
        assert_eq!(
            space().unordered_mask(),
            vec![false, false, true, true, false]
        );
    }

    #[test]
    fn lerp_clamps() {
        assert_eq!(lerp(-1.0, 0.0, 10.0), 0.0);
        assert_eq!(lerp(2.0, 0.0, 10.0), 10.0);
        assert_eq!(lerp(0.25, 0.0, 8.0), 2.0);
    }

    #[test]
    fn json_roundtrip_structure() {
        let j = space().to_json();
        let text = j.to_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.as_arr().unwrap().len(), 5);
        assert_eq!(
            back.idx(0).unwrap().get("name").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn json_roundtrip_full_space() {
        let s = space();
        let back = ParamSpace::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        assert!(ParamSpace::from_json(&Value::Null).is_err());
        assert!(ParamSpace::from_json(&Value::Arr(vec![Value::obj(vec![(
            "name",
            Value::Str("p".into()),
        )])]))
        .is_err());
        // Constructor invariants hold through deserialization too: empty
        // ranges/choice lists must be rejected, not loaded as panic bombs.
        for bad in [
            r#"[{"name":"c","kind":"categorical","choices":[]}]"#,
            r#"[{"name":"f","kind":"float","lo":2.0,"hi":1.0}]"#,
            r#"[{"name":"i","kind":"int","lo":5,"hi":1}]"#,
            r#"[{"name":"l","kind":"float","lo":-1.0,"hi":1.0,"log":true}]"#,
        ] {
            let doc = crate::util::json::parse(bad).unwrap();
            assert!(ParamSpace::from_json(&doc).is_err(), "{bad}");
        }
    }
}
