//! FNV-1a 64-bit hashing — stable across platforms and processes (unlike
//! `DefaultHasher`), which checkpoint fingerprints and cache keys
//! require. One implementation shared by the checkpoint upstream-hash
//! chain and the serving memo cache.

/// FNV-1a over a byte stream.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a over a u64 stream (e.g. f64 bit patterns), byte order fixed to
/// little-endian so the hash is platform-stable.
pub fn fnv1a_u64s(words: &[u64]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &w in words {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors_and_stream_equivalence() {
        // FNV-1a reference values.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        // The u64 variant must equal hashing the same little-endian bytes.
        let words = [1u64, u64::MAX, 0x0123_4567_89ab_cdef];
        let mut bytes = Vec::new();
        for w in words {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        assert_eq!(fnv1a_u64s(&words), fnv1a(&bytes));
        assert_ne!(fnv1a_u64s(&[1, 2]), fnv1a_u64s(&[2, 1]));
    }
}
