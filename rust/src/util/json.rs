//! Minimal JSON: parser + serializer. Used for the artifact manifest,
//! experiment configs, decision-tree serialization and figure outputs.
//! (serde is unavailable offline — DESIGN.md §1.)

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are f64 (adequate for manifests/configs).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Arr(a) => a.get(i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Build an object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        let (nl, pad, pad1) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * (level + 1)),
                " ".repeat(w * level),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Value::Str(s) => escape_into(s, out),
            Value::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad);
                    v.write(out, indent, level + 1);
                }
                out.push_str(nl);
                out.push_str(&pad1);
                out.push(']');
            }
            Value::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad);
                    escape_into(k, out);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                out.push_str(nl);
                out.push_str(&pad1);
                out.push('}');
            }
        }
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }
    fn lit(&mut self, s: &str, v: Value) -> Result<Value, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                other => return Err(format!("bad object sep {:?} at {}", other, self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                other => return Err(format!("bad array sep {:?} at {}", other, self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i + 1..self.i + 5)
                                    .ok_or("truncated \\u escape")?,
                            )
                            .map_err(|e| e.to_string())?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 scalar.
                    let rest = &self.b[self.i..];
                    let text = std::str::from_utf8(rest).map_err(|e| e.to_string())?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|e| format!("bad number '{text}': {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b"),
            Some(&Value::Null)
        );
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Value::obj(vec![
            ("xs", Value::Arr(vec![Value::Num(1.0), Value::Num(2.5)])),
            ("name", Value::Str("dgetrf".into())),
            ("ok", Value::Bool(true)),
        ]);
        let p = v.to_pretty();
        assert_eq!(parse(&p).unwrap(), v);
        assert!(p.contains('\n'));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("nope").is_err());
    }

    #[test]
    fn escapes() {
        let v = Value::Str("a\"b\\c\nd".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            parse(r#""é""#).unwrap(),
            Value::Str("\u{e9}".to_string())
        );
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Value::Num(30000.0).to_string(), "30000");
        assert_eq!(Value::Num(1.5).to_string(), "1.5");
    }

    #[test]
    fn real_manifest_shape() {
        let m = r#"{"kernel":"lu_blocked","variants":[{"path":"a.hlo.txt","n":64,"block":8}]}"#;
        let v = parse(m).unwrap();
        let vs = v.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(vs[0].get("n").unwrap().as_usize(), Some(64));
    }
}
