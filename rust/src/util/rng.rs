//! Deterministic pseudo-random number generation: xoshiro256++ seeded via
//! SplitMix64. Every stochastic component in the crate (samplers, GA, TPE,
//! CMA-ES, simulator noise) takes an explicit `Rng` so experiments are
//! reproducible from a single seed.

/// xoshiro256++ generator (Blackman & Vigna). Fast, 256-bit state,
/// passes BigCrush; more than adequate for sampling experiments.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box-Muller transform.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child generator (for per-thread streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_A5A5_DEAD_BEEF)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Rejection-free multiply-shift; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform i64 in [lo, hi] inclusive.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Log-normal multiplicative noise factor: exp(N(0, sigma)).
    pub fn lognormal(&mut self, sigma: f64) -> f64 {
        (sigma * self.normal()).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample `k` distinct indices from 0..n (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            let mut p = self.permutation(n);
            p.truncate(k);
            return p;
        }
        // Floyd's algorithm for sparse selection.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        self.shuffle(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_is_centered() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform(-1.0, 1.0)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn int_range_inclusive() {
        let mut r = Rng::new(5);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let v = r.int_range(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(17);
        for _ in 0..1000 {
            assert!(r.lognormal(0.5) > 0.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(23);
        for &(n, k) in &[(100, 5), (100, 90), (10, 10), (1000, 1)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut a = Rng::new(29);
        let mut b = a.fork();
        let x: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let y: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(x, y);
    }
}
