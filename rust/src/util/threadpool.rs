//! Parallel map over std::thread::scope — the sampling harness substrate.
//!
//! The paper's pipeline spends most wall-clock time collecting kernel
//! samples; MLKAPS batches each sampling iteration across workers. tokio is
//! unavailable offline, so the coordinator uses scoped OS threads with a
//! work-stealing index (atomic cursor), which is ideal for CPU-bound
//! sample evaluation anyway.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default (cores, capped at 16).
///
/// The `MLKAPS_THREADS` environment variable overrides the detected
/// count (any integer ≥ 1); CI runs the whole test suite under
/// `MLKAPS_THREADS=1` as well as the default, so every adaptive
/// "parallel above N rows" path is exercised in both regimes.
pub fn default_threads() -> usize {
    if let Some(t) = env_threads() {
        return t;
    }
    std::thread::available_parallelism()
        .map(|n| n.get().min(16))
        .unwrap_or(4)
}

/// Parse the `MLKAPS_THREADS` override (None when unset/empty/invalid).
fn env_threads() -> Option<usize> {
    std::env::var("MLKAPS_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&t| t >= 1)
}

/// Output slot vector shared across workers by raw pointer.
///
/// Soundness contract: the atomic work-stealing cursor hands every index
/// to exactly one worker, so all writes hit disjoint slots, and the
/// `thread::scope` join supplies the happens-before edge for the final
/// read. This replaces the old per-slot `Mutex`, whose lock/unlock pair
/// on every result made the inner loop a serialization point for cheap
/// work items.
struct Slots<R>(*mut Option<R>);
unsafe impl<R: Send> Sync for Slots<R> {}

/// Apply `f` to every item in parallel, preserving input order.
///
/// `threads == 1` runs inline (deterministic debugging path). The inner
/// loop is lock-free: workers claim indices from an atomic cursor and
/// write results through disjoint slots.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads == 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);
    let slots = Slots(out.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                // SAFETY: `i` was claimed by exactly one fetch_add winner
                // and is in-bounds; no other thread touches slot `i`. The
                // scope join orders these writes before `out` is read.
                unsafe { *slots.0.add(i) = Some(r) };
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker panicked")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = par_map(&items, 8, |_, &x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_inline() {
        let items = vec![1, 2, 3];
        assert_eq!(par_map(&items, 1, |i, &x| x + i), vec![1, 3, 5]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<i32> = vec![];
        assert!(par_map(&items, 4, |_, &x| x).is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let items = vec![7];
        assert_eq!(par_map(&items, 64, |_, &x| x), vec![7]);
    }

    #[test]
    fn heap_allocated_results_preserve_order() {
        // Non-Copy results through the raw slot writes: ordering, content
        // and drops must all be correct.
        let items: Vec<usize> = (0..300).collect();
        let out = par_map(&items, 7, |i, &x| vec![format!("{i}:{x}")]);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(v, &vec![format!("{i}:{i}")]);
        }
    }

    #[test]
    fn all_items_processed_exactly_once() {
        use std::sync::atomic::AtomicU64;
        let counter = AtomicU64::new(0);
        let items: Vec<u64> = (0..500).collect();
        let out = par_map(&items, 6, |_, &x| {
            counter.fetch_add(1, Ordering::Relaxed);
            x
        });
        assert_eq!(counter.load(Ordering::Relaxed), 500);
        assert_eq!(out.len(), 500);
    }
}
