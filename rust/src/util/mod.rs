//! Zero-dependency substrates: RNG, JSON, statistics, thread pool and
//! memory telemetry. Built in-tree because the build environment is fully
//! offline (see DESIGN.md §1, substitution index).

pub mod failpoint;
pub mod hash;
pub mod json;
pub mod rng;
pub mod stats;
pub mod telemetry;
pub mod threadpool;
