//! Descriptive statistics used across samplers, metrics and reports:
//! means, variance, quantiles, geometric means, coefficient of variation
//! and the Student-t critical values HVS uses for its variance upper bound.

/// Arithmetic mean (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance (n-1 denominator); 0.0 when n < 2.
pub fn variance(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Coefficient of variation (sd / |mean|); used by HVS-relative.
/// Returns 0 when the mean is ~0 to avoid blow-up.
pub fn coeff_variation(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m.abs() < 1e-300 {
        return 0.0;
    }
    std_dev(xs) / m.abs()
}

/// Geometric mean of strictly-positive values (the paper's speedup metric).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let logsum: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (logsum / xs.len() as f64).exp()
}

/// Median (linear-interpolated); 0.0 for empty input.
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Quantile q in [0,1] with linear interpolation between order statistics.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Two-sided Student-t critical value at 95% confidence for `df` degrees of
/// freedom. Table lookup + asymptote, as used by HVS's conservative
/// variance estimator (de Oliveira Castro et al., Euro-Par 2012).
pub fn t_crit_95(df: usize) -> f64 {
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228,
        2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086,
        2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    match df {
        0 => f64::INFINITY,
        d if d <= 30 => TABLE[d - 1],
        d if d <= 60 => 2.02,
        d if d <= 120 => 1.98,
        _ => 1.96,
    }
}

/// Mean absolute error between predictions and targets.
pub fn mae(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    mean(&pred
        .iter()
        .zip(truth)
        .map(|(p, t)| (p - t).abs())
        .collect::<Vec<_>>())
}

/// Root mean squared error.
pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    mean(&pred
        .iter()
        .zip(truth)
        .map(|(p, t)| (p - t) * (p - t))
        .collect::<Vec<_>>())
    .sqrt()
}

/// Mean absolute percentage error (targets near zero are floored).
pub fn mape(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    mean(&pred
        .iter()
        .zip(truth)
        .map(|(p, t)| ((p - t) / t.abs().max(1e-12)).abs())
        .collect::<Vec<_>>())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((variance(&xs) - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
        assert_eq!(variance(&[1.0]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn geomean_of_speedups() {
        // geomean(2, 0.5) == 1 — the canonical reason the paper uses it.
        assert!((geomean(&[2.0, 0.5]) - 1.0).abs() < 1e-12);
        assert!((geomean(&[1.3, 1.3, 1.3]) - 1.3).abs() < 1e-12);
    }

    #[test]
    fn median_and_quantiles() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(median(&xs), 2.0);
        let ys = [1.0, 2.0, 3.0, 4.0];
        assert!((median(&ys) - 2.5).abs() < 1e-12);
        assert_eq!(quantile(&ys, 0.0), 1.0);
        assert_eq!(quantile(&ys, 1.0), 4.0);
    }

    #[test]
    fn t_table_monotone_decreasing() {
        assert!(t_crit_95(1) > t_crit_95(2));
        assert!(t_crit_95(10) > t_crit_95(30));
        assert!(t_crit_95(30) > t_crit_95(1000));
        assert!((t_crit_95(1_000_000) - 1.96).abs() < 1e-12);
        assert!(t_crit_95(0).is_infinite());
    }

    #[test]
    fn error_metrics() {
        let p = [1.0, 2.0, 3.0];
        let t = [1.0, 1.0, 5.0];
        assert!((mae(&p, &t) - 1.0).abs() < 1e-12);
        assert!((rmse(&p, &t) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert!((mape(&p, &t) - (0.0 + 1.0 + 0.4) / 3.0).abs() < 1e-12);
    }

    #[test]
    fn coeff_variation_zero_mean() {
        assert_eq!(coeff_variation(&[1.0, -1.0]), 0.0);
        assert!(coeff_variation(&[10.0, 12.0, 8.0]) > 0.0);
    }
}
