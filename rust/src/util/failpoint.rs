//! Deterministic, zero-dependency fault injection for chaos testing.
//!
//! A **failpoint** is a named site at an I/O or concurrency choke point
//! (checkpoint commits, daemon socket reads, batcher enqueue, …) where a
//! fault can be injected on demand. Sites are compiled in permanently
//! but cost a single relaxed atomic load when disarmed, so they stay in
//! release builds and the serving hot path (the gated smoke benches run
//! with failpoints disarmed and must not move).
//!
//! Activation is either programmatic ([`arm`] / [`arm_scoped`], what the
//! chaos suites use) or via the environment at first use:
//!
//! ```text
//! MLKAPS_FAILPOINTS="checkpoint.commit=err@2;daemon.read=eof@0.05"
//! ```
//!
//! Each clause is `site=fault[@arg]`:
//!
//! * fault — `err` (the operation fails with an error), `eof` (the
//!   operation observes end-of-stream / absent data), `panic` (the
//!   thread panics; for exercising the daemon's supervisors).
//! * no arg — fire on every hit.
//! * integer arg (`err@2`) — fire exactly once, on the Nth hit
//!   (0-based), modelling "the third write dies".
//! * fractional arg (`eof@0.05`) — fire each hit with that probability,
//!   drawn from a [`crate::util::rng::Rng`] seeded per site from
//!   `MLKAPS_FAILPOINTS_SEED` (default seed if unset), so a chaotic run
//!   is exactly reproducible from its spec + seed.
//!
//! Site names are a closed registry ([`registered`]): arming an unknown
//! site is an error, so a typo in a spec fails loudly instead of
//! silently injecting nothing.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, Once, RwLock};

use crate::util::hash::fnv1a;
use crate::util::rng::Rng;

/// The registered failpoint sites. Each constant names one choke point;
/// `ALL` is the closed registry the spec parser validates against and
/// the chaos suites enumerate.
pub mod sites {
    /// Writing a stage artifact's temp file (`pipeline/checkpoint.rs`).
    pub const CHECKPOINT_WRITE: &str = "checkpoint.write";
    /// Fsyncing the temp file before the commit rename.
    pub const CHECKPOINT_FSYNC: &str = "checkpoint.fsync";
    /// The atomic rename committing an artifact (+ directory fsync).
    pub const CHECKPOINT_COMMIT: &str = "checkpoint.commit";
    /// Reading a stage artifact back (resume / reload-after-write).
    pub const CHECKPOINT_READ: &str = "checkpoint.read";
    /// Stage-envelope upstream-hash chain verification.
    pub const CHECKPOINT_VERIFY: &str = "checkpoint.verify";
    /// Full chain-verified artifact load in `runtime/serving.rs`.
    pub const SERVING_LOAD: &str = "serving.load";
    /// Accepting a connection in the daemon's accept loop.
    pub const DAEMON_ACCEPT: &str = "daemon.accept";
    /// Reading a request frame/line off a connection.
    pub const DAEMON_READ: &str = "daemon.read";
    /// Writing a response frame/line to a connection.
    pub const DAEMON_WRITE: &str = "daemon.write";
    /// Inside a per-connection handler (panic here to test that one
    /// connection's death never takes the daemon with it).
    pub const DAEMON_CONN: &str = "daemon.conn";
    /// Enqueueing a decide job into the batch queue.
    pub const BATCHER_ENQUEUE: &str = "batcher.enqueue";
    /// Inside the batcher's flush (panic here to test the batcher
    /// supervisor's restart path).
    pub const BATCHER_FLUSH: &str = "batcher.flush";
    /// A hot-reload poll of a watched checkpoint directory.
    pub const RELOAD_POLL: &str = "reload.poll";
    /// Granting a stage-3 shard lease to a cluster worker
    /// (`runtime/cluster/coordinator.rs`).
    pub const CLUSTER_LEASE: &str = "cluster.lease";
    /// Renewing a worker's lease on heartbeat (err here makes the
    /// coordinator refuse renewal, so the lease expires under load).
    pub const CLUSTER_HEARTBEAT: &str = "cluster.heartbeat";
    /// Accepting a worker's shard result upload.
    pub const CLUSTER_RESULT: &str = "cluster.result";
    /// The coordinator's final merge of shard artifacts into the
    /// chain-verified run.
    pub const CLUSTER_MERGE: &str = "cluster.merge";
    /// Inside a worker, between taking a lease and uploading its result
    /// (panic here models a worker dying mid-shard).
    pub const CLUSTER_WORKER_SHARD: &str = "cluster.worker_shard";
    /// A worker's result upload attempt (`runtime/cluster/worker.rs`;
    /// err here makes every upload fail, exercising the spool path).
    pub const CLUSTER_UPLOAD: &str = "cluster.upload";
    /// Spawning a fleet child process (`runtime/fleet/supervisor.rs`).
    pub const FLEET_SPAWN: &str = "fleet.spawn";
    /// A supervisor health probe of a fleet child (err ⇒ the probe
    /// fails as if the child were hung).
    pub const FLEET_HEALTH: &str = "fleet.health";
    /// Sending DRAIN to an old child during a rolling redeploy.
    pub const FLEET_DRAIN: &str = "fleet.drain";
    /// Reserved for unit tests (never evaluated by production code).
    pub const TEST_PROBE: &str = "test.probe";

    pub const ALL: &[&str] = &[
        CHECKPOINT_WRITE,
        CHECKPOINT_FSYNC,
        CHECKPOINT_COMMIT,
        CHECKPOINT_READ,
        CHECKPOINT_VERIFY,
        SERVING_LOAD,
        DAEMON_ACCEPT,
        DAEMON_READ,
        DAEMON_WRITE,
        DAEMON_CONN,
        BATCHER_ENQUEUE,
        BATCHER_FLUSH,
        RELOAD_POLL,
        CLUSTER_LEASE,
        CLUSTER_HEARTBEAT,
        CLUSTER_RESULT,
        CLUSTER_MERGE,
        CLUSTER_WORKER_SHARD,
        CLUSTER_UPLOAD,
        FLEET_SPAWN,
        FLEET_HEALTH,
        FLEET_DRAIN,
        TEST_PROBE,
    ];
}

/// Every registered site name (the closed registry).
pub fn registered() -> &'static [&'static str] {
    sites::ALL
}

/// What an armed site injects when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// The guarded operation fails with an injected error.
    Err,
    /// The guarded operation observes end-of-stream / missing data.
    Eof,
    /// The current thread panics (supervisor testing).
    Panic,
}

impl Fault {
    pub fn name(&self) -> &'static str {
        match self {
            Fault::Err => "err",
            Fault::Eof => "eof",
            Fault::Panic => "panic",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum Trigger {
    Every,
    /// Fire exactly once, on the Nth evaluation (0-based).
    Nth(u64),
    /// Fire each evaluation with probability p (seeded, reproducible).
    Prob(f64),
}

struct Rule {
    fault: Fault,
    trigger: Trigger,
    hits: AtomicU64,
    rng: Mutex<Rng>,
}

impl Rule {
    fn fire(&self) -> Option<Fault> {
        let hit = self.hits.fetch_add(1, Ordering::Relaxed);
        let fired = match self.trigger {
            Trigger::Every => true,
            Trigger::Nth(n) => hit == n,
            Trigger::Prob(p) => {
                let mut rng = self.rng.lock().unwrap_or_else(|e| e.into_inner());
                rng.bool(p)
            }
        };
        fired.then_some(self.fault)
    }
}

struct Config {
    rules: BTreeMap<&'static str, Rule>,
}

/// Fast-path flag: one relaxed load decides "disarmed, do nothing".
static ARMED: AtomicBool = AtomicBool::new(false);
/// Active rules. Only read when `ARMED` is set (the cold path).
static REGISTRY: RwLock<Option<Config>> = RwLock::new(None);
/// First-use environment activation; claimed (as a no-op) by
/// programmatic [`arm`] so a later env read can't clobber a test's spec.
static ENV_INIT: Once = Once::new();

const DEFAULT_SEED: u64 = 0x6d6c_6b61_7073; // "mlkaps" in spirit

fn env_seed() -> u64 {
    std::env::var("MLKAPS_FAILPOINTS_SEED")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(DEFAULT_SEED)
}

fn ensure_env_init() {
    ENV_INIT.call_once(|| {
        if let Ok(spec) = std::env::var("MLKAPS_FAILPOINTS") {
            if !spec.trim().is_empty() {
                if let Err(e) = install(&spec, env_seed()) {
                    // A malformed env spec must not silently disable
                    // chaos runs; fail loudly on stderr and stay
                    // disarmed (the chaos CI greps for this line).
                    eprintln!("mlkaps: invalid MLKAPS_FAILPOINTS: {e}");
                }
            }
        }
    });
}

fn canonical(site: &str) -> Result<&'static str, String> {
    sites::ALL
        .iter()
        .copied()
        .find(|s| *s == site)
        .ok_or_else(|| {
            format!("unknown failpoint site '{site}' (registered: {})", sites::ALL.join(", "))
        })
}

fn parse_clause(clause: &str, seed: u64) -> Result<(&'static str, Rule), String> {
    let (site, action) = clause
        .split_once('=')
        .ok_or_else(|| format!("failpoint clause '{clause}' is not site=fault[@arg]"))?;
    let site = canonical(site.trim())?;
    let action = action.trim();
    let (fault, arg) = match action.split_once('@') {
        Some((f, a)) => (f.trim(), Some(a.trim())),
        None => (action, None),
    };
    let fault = match fault {
        "err" => Fault::Err,
        "eof" => Fault::Eof,
        "panic" => Fault::Panic,
        other => return Err(format!("unknown fault '{other}' (err, eof, panic)")),
    };
    let trigger = match arg {
        None => Trigger::Every,
        Some(a) => {
            if let Ok(n) = a.parse::<u64>() {
                Trigger::Nth(n)
            } else {
                let p: f64 = a
                    .parse()
                    .map_err(|_| format!("failpoint arg '{a}' is neither a hit index nor a probability"))?;
                if !(p > 0.0 && p <= 1.0) {
                    return Err(format!("failpoint probability {p} is outside (0, 1]"));
                }
                Trigger::Prob(p)
            }
        }
    };
    Ok((
        site,
        Rule {
            fault,
            trigger,
            hits: AtomicU64::new(0),
            // Per-site stream: reproducible and independent of how many
            // other sites fire in between.
            rng: Mutex::new(Rng::new(seed ^ fnv1a(site.as_bytes()))),
        },
    ))
}

fn install(spec: &str, seed: u64) -> Result<(), String> {
    let mut rules = BTreeMap::new();
    for clause in spec.split(';') {
        let clause = clause.trim();
        if clause.is_empty() {
            continue;
        }
        let (site, rule) = parse_clause(clause, seed)?;
        rules.insert(site, rule);
    }
    let mut guard = REGISTRY.write().unwrap_or_else(|e| e.into_inner());
    let armed = !rules.is_empty();
    *guard = armed.then_some(Config { rules });
    // Publish the flag while holding the write lock so check() can
    // never observe ARMED set with yesterday's rules.
    ARMED.store(armed, Ordering::SeqCst);
    Ok(())
}

/// Arm the given spec (`site=fault[@arg];...`), replacing any active
/// one. Hit counters and per-site RNG streams start fresh. Errors on an
/// unknown site or malformed clause, leaving the previous spec armed.
pub fn arm(spec: &str) -> Result<(), String> {
    arm_with_seed(spec, env_seed())
}

/// [`arm`] with an explicit RNG seed for probabilistic triggers.
pub fn arm_with_seed(spec: &str, seed: u64) -> Result<(), String> {
    // Claim env-activation so a later first-hit can't overwrite this.
    ENV_INIT.call_once(|| {});
    install(spec, seed)
}

/// Disarm every site. The hot path goes back to one relaxed load.
pub fn disarm() {
    ENV_INIT.call_once(|| {});
    let mut guard = REGISTRY.write().unwrap_or_else(|e| e.into_inner());
    *guard = None;
    ARMED.store(false, Ordering::SeqCst);
}

/// RAII arming for tests: the spec stays armed until the guard drops.
pub struct ScopedFailpoints(());

impl Drop for ScopedFailpoints {
    fn drop(&mut self) {
        disarm();
    }
}

/// Arm a spec and get a guard that disarms on drop.
pub fn arm_scoped(spec: &str) -> Result<ScopedFailpoints, String> {
    arm(spec)?;
    Ok(ScopedFailpoints(()))
}

/// Evaluate a site: `None` (the overwhelmingly common answer) means
/// proceed normally; `Some(fault)` means the caller must act out the
/// injected fault. Disarmed cost: one relaxed atomic load (plus a
/// one-time env check).
pub fn check(site: &str) -> Option<Fault> {
    ensure_env_init();
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    let guard = REGISTRY.read().unwrap_or_else(|e| e.into_inner());
    guard.as_ref()?.rules.get(site)?.fire()
}

/// Guard an operation whose only failure mode is an error `Result`:
/// `Err`/`Eof` faults become an injected error, `Panic` panics.
pub fn fail(site: &str) -> Result<(), String> {
    match check(site) {
        None => Ok(()),
        Some(Fault::Panic) => panic!("failpoint {site}: injected panic"),
        Some(f) => Err(format!("failpoint {site}: injected {}", f.name())),
    }
}

/// Times a site has been evaluated under the currently armed spec
/// (0 when the site is not armed). Chaos-test observability.
pub fn hits(site: &str) -> u64 {
    let guard = REGISTRY.read().unwrap_or_else(|e| e.into_inner());
    guard
        .as_ref()
        .and_then(|c| c.rules.get(site))
        .map(|r| r.hits.load(Ordering::Relaxed))
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Failpoints are process-global; unit tests that arm them must not
    /// interleave (other modules' tests never arm `test.probe`).
    static GATE: Mutex<()> = Mutex::new(());

    fn gate() -> std::sync::MutexGuard<'static, ()> {
        GATE.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disarmed_sites_never_fire() {
        let _g = gate();
        disarm();
        assert_eq!(check(sites::TEST_PROBE), None);
        assert!(fail(sites::TEST_PROBE).is_ok());
        assert_eq!(hits(sites::TEST_PROBE), 0);
    }

    #[test]
    fn every_and_nth_triggers() {
        let _g = gate();
        {
            let _fp = arm_scoped("test.probe=err").unwrap();
            assert_eq!(check(sites::TEST_PROBE), Some(Fault::Err));
            assert_eq!(check(sites::TEST_PROBE), Some(Fault::Err));
            assert!(fail(sites::TEST_PROBE).unwrap_err().contains("test.probe"));
            assert_eq!(hits(sites::TEST_PROBE), 3);
        }
        // Nth is one-shot: only the (N+1)-th evaluation fires.
        let _fp = arm_scoped(" test.probe = eof@2 ").unwrap();
        assert_eq!(check(sites::TEST_PROBE), None);
        assert_eq!(check(sites::TEST_PROBE), None);
        assert_eq!(check(sites::TEST_PROBE), Some(Fault::Eof));
        assert_eq!(check(sites::TEST_PROBE), None);
    }

    #[test]
    fn probability_stream_is_reproducible() {
        let _g = gate();
        let run = || -> Vec<bool> {
            let _fp = arm_scoped("test.probe=err@0.3").unwrap();
            (0..64).map(|_| check(sites::TEST_PROBE).is_some()).collect()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same spec + seed must fire identically");
        let fired = a.iter().filter(|&&f| f).count();
        assert!(fired > 0 && fired < 64, "p=0.3 over 64 draws fired {fired} times");
    }

    #[test]
    fn specs_validate_sites_and_shapes() {
        let _g = gate();
        assert!(arm("nope.site=err").is_err(), "unknown site");
        assert!(arm("test.probe").is_err(), "missing fault");
        assert!(arm("test.probe=explode").is_err(), "unknown fault");
        assert!(arm("test.probe=err@1.5").is_err(), "probability > 1");
        assert!(arm("test.probe=err@wat").is_err(), "garbage arg");
        // A failed arm leaves the process disarmed (nothing installed).
        assert_eq!(check(sites::TEST_PROBE), None);
        // Multi-clause specs parse; empty clauses are tolerated.
        let _fp =
            arm_scoped("test.probe=panic@0; ;checkpoint.commit=err@2;").unwrap();
        assert_eq!(hits(sites::CHECKPOINT_COMMIT), 0);
        disarm();
    }

    #[test]
    #[should_panic(expected = "injected panic")]
    fn panic_fault_panics_through_fail() {
        let _g = gate();
        let _fp = arm_scoped("test.probe=panic").unwrap();
        let _ = fail(sites::TEST_PROBE);
    }

    #[test]
    fn registry_is_closed_and_deduplicated() {
        let mut all: Vec<&str> = registered().to_vec();
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "duplicate site names");
        assert!(registered().contains(&sites::DAEMON_READ));
    }
}
