//! Memory + time telemetry for the scaling experiment (Fig 14).
//!
//! Two complementary views:
//! * [`ModelFootprint`] — *algorithmic* memory: bytes held by a tuner's
//!   model state (GBDT trees vs the GP's dense covariance). This is the
//!   quantity whose growth law Fig 14 demonstrates, and it is
//!   machine-independent.
//! * [`rss_bytes`] — real process RSS from /proc/self/status, reported
//!   alongside for context.

use std::time::Instant;

/// Types that can report the size of their live model state.
pub trait ModelFootprint {
    /// Approximate heap bytes held by the model (data structures that grow
    /// with the number of samples/tasks).
    fn model_bytes(&self) -> usize;
}

/// Current resident set size of this process in bytes (Linux), or None.
pub fn rss_bytes() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: usize = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Tracks the peak of a monotonically sampled quantity plus elapsed time.
#[derive(Debug)]
pub struct PeakTracker {
    start: Instant,
    peak: usize,
}

impl PeakTracker {
    pub fn new() -> Self {
        PeakTracker { start: Instant::now(), peak: 0 }
    }
    /// Record an observation; keeps the max.
    pub fn observe(&mut self, bytes: usize) {
        self.peak = self.peak.max(bytes);
    }
    pub fn peak_bytes(&self) -> usize {
        self.peak
    }
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Default for PeakTracker {
    fn default() -> Self {
        Self::new()
    }
}

/// Simple stopwatch for phase timing (sampling vs modeling vs optimizing).
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_is_positive_on_linux() {
        let rss = rss_bytes().expect("linux /proc should exist");
        assert!(rss > 1 << 20, "rss={rss}"); // > 1 MiB
    }

    #[test]
    fn peak_tracker_keeps_max() {
        let mut t = PeakTracker::new();
        t.observe(10);
        t.observe(100);
        t.observe(50);
        assert_eq!(t.peak_bytes(), 100);
        assert!(t.elapsed_secs() >= 0.0);
    }

    #[test]
    fn stopwatch_advances() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.secs() >= 0.004);
    }
}
