//! Memory + time telemetry for the scaling experiment (Fig 14).
//!
//! Two complementary views:
//! * [`ModelFootprint`] — *algorithmic* memory: bytes held by a tuner's
//!   model state (GBDT trees vs the GP's dense covariance). This is the
//!   quantity whose growth law Fig 14 demonstrates, and it is
//!   machine-independent.
//! * [`rss_bytes`] — real process RSS from /proc/self/status, reported
//!   alongside for context.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Lock-free hit/miss counters for caches on concurrent serving paths
/// (e.g. the decision-runtime input memo). Relaxed ordering: the counts
/// are monitoring data, not synchronization.
#[derive(Debug, Default)]
pub struct HitCounters {
    hits: AtomicU64,
    misses: AtomicU64,
}

impl HitCounters {
    pub fn new() -> Self {
        HitCounters::default()
    }

    /// Record a cache hit.
    pub fn hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a cache miss.
    pub fn miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Total lookups observed so far.
    pub fn total(&self) -> u64 {
        self.hits() + self.misses()
    }

    /// Hit fraction in [0,1]; 0.0 before any lookup.
    pub fn hit_rate(&self) -> f64 {
        let total = self.total();
        if total == 0 {
            0.0
        } else {
            self.hits() as f64 / total as f64
        }
    }

    /// Zero both counters (e.g. between bench phases).
    pub fn reset(&self) {
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }
}

/// Lock-free counters for the serving daemon's recovery paths, reported
/// under the `STATS` verb. Relaxed ordering for the same reason as
/// [`HitCounters`]: these observe failures, they don't synchronize
/// recovery. A regression that silently stops a recovery path from
/// firing shows up as a counter that no longer moves in the chaos
/// suites.
#[derive(Debug, Default)]
pub struct RecoveryCounters {
    /// Supervised threads (batcher, reload poller) restarted after a
    /// caught panic.
    pub restarts: AtomicU64,
    /// Decide requests shed with an `overloaded` response because the
    /// batch queue was full.
    pub sheds: AtomicU64,
    /// Connections closed by the read/write timeout.
    pub timeouts: AtomicU64,
    /// Malformed inputs answered with an error response: oversized or
    /// truncated frames, non-UTF-8 payloads, unparseable requests.
    pub malformed: AtomicU64,
    /// Per-connection handlers that panicked (each kills only its own
    /// connection).
    pub conn_panics: AtomicU64,
}

impl RecoveryCounters {
    pub fn new() -> Self {
        RecoveryCounters::default()
    }

    /// (restarts, sheds, timeouts, malformed, conn_panics) snapshot.
    pub fn snapshot(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.restarts.load(Ordering::Relaxed),
            self.sheds.load(Ordering::Relaxed),
            self.timeouts.load(Ordering::Relaxed),
            self.malformed.load(Ordering::Relaxed),
            self.conn_panics.load(Ordering::Relaxed),
        )
    }
}

/// Types that can report the size of their live model state.
pub trait ModelFootprint {
    /// Approximate heap bytes held by the model (data structures that grow
    /// with the number of samples/tasks).
    fn model_bytes(&self) -> usize;
}

/// Current resident set size of this process in bytes (Linux), or None.
pub fn rss_bytes() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: usize = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Tracks the peak of a monotonically sampled quantity plus elapsed time.
#[derive(Debug)]
pub struct PeakTracker {
    start: Instant,
    peak: usize,
}

impl PeakTracker {
    pub fn new() -> Self {
        PeakTracker { start: Instant::now(), peak: 0 }
    }
    /// Record an observation; keeps the max.
    pub fn observe(&mut self, bytes: usize) {
        self.peak = self.peak.max(bytes);
    }
    pub fn peak_bytes(&self) -> usize {
        self.peak
    }
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Default for PeakTracker {
    fn default() -> Self {
        Self::new()
    }
}

/// Simple stopwatch for phase timing (sampling vs modeling vs optimizing).
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_counters_track_rate() {
        let c = HitCounters::new();
        assert_eq!(c.hit_rate(), 0.0);
        c.hit();
        c.hit();
        c.hit();
        c.miss();
        assert_eq!(c.hits(), 3);
        assert_eq!(c.misses(), 1);
        assert_eq!(c.total(), 4);
        assert!((c.hit_rate() - 0.75).abs() < 1e-12);
        c.reset();
        assert_eq!(c.total(), 0);
    }

    #[test]
    fn recovery_counters_snapshot_in_field_order() {
        let c = RecoveryCounters::new();
        assert_eq!(c.snapshot(), (0, 0, 0, 0, 0));
        c.restarts.fetch_add(1, Ordering::Relaxed);
        c.sheds.fetch_add(2, Ordering::Relaxed);
        c.timeouts.fetch_add(3, Ordering::Relaxed);
        c.malformed.fetch_add(4, Ordering::Relaxed);
        c.conn_panics.fetch_add(5, Ordering::Relaxed);
        assert_eq!(c.snapshot(), (1, 2, 3, 4, 5));
    }

    #[test]
    fn rss_is_positive_on_linux() {
        let rss = rss_bytes().expect("linux /proc should exist");
        assert!(rss > 1 << 20, "rss={rss}"); // > 1 MiB
    }

    #[test]
    fn peak_tracker_keeps_max() {
        let mut t = PeakTracker::new();
        t.observe(10);
        t.observe(100);
        t.observe(50);
        assert_eq!(t.peak_bytes(), 100);
        assert!(t.elapsed_secs() >= 0.0);
    }

    #[test]
    fn stopwatch_advances() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.secs() >= 0.004);
    }
}
