//! Memory + time telemetry for the scaling experiment (Fig 14).
//!
//! Two complementary views:
//! * [`ModelFootprint`] — *algorithmic* memory: bytes held by a tuner's
//!   model state (GBDT trees vs the GP's dense covariance). This is the
//!   quantity whose growth law Fig 14 demonstrates, and it is
//!   machine-independent.
//! * [`rss_bytes`] — real process RSS from /proc/self/status, reported
//!   alongside for context.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Lock-free hit/miss counters for caches on concurrent serving paths
/// (e.g. the decision-runtime input memo). Relaxed ordering: the counts
/// are monitoring data, not synchronization.
#[derive(Debug, Default)]
pub struct HitCounters {
    hits: AtomicU64,
    misses: AtomicU64,
}

impl HitCounters {
    pub fn new() -> Self {
        HitCounters::default()
    }

    /// Record a cache hit.
    pub fn hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a cache miss.
    pub fn miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Total lookups observed so far.
    pub fn total(&self) -> u64 {
        self.hits() + self.misses()
    }

    /// Hit fraction in [0,1]; 0.0 before any lookup.
    pub fn hit_rate(&self) -> f64 {
        let total = self.total();
        if total == 0 {
            0.0
        } else {
            self.hits() as f64 / total as f64
        }
    }

    /// Zero both counters (e.g. between bench phases).
    pub fn reset(&self) {
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }
}

/// Lock-free counters for the serving daemon's recovery paths, reported
/// under the `STATS` verb. Relaxed ordering for the same reason as
/// [`HitCounters`]: these observe failures, they don't synchronize
/// recovery. A regression that silently stops a recovery path from
/// firing shows up as a counter that no longer moves in the chaos
/// suites.
#[derive(Debug, Default)]
pub struct RecoveryCounters {
    /// Supervised threads (batcher, reload poller) restarted after a
    /// caught panic.
    pub restarts: AtomicU64,
    /// Decide requests shed with an `overloaded` response because the
    /// batch queue was full.
    pub sheds: AtomicU64,
    /// Connections closed by the read/write timeout.
    pub timeouts: AtomicU64,
    /// Malformed inputs answered with an error response: oversized or
    /// truncated frames, non-UTF-8 payloads, unparseable requests.
    pub malformed: AtomicU64,
    /// Per-connection handlers that panicked (each kills only its own
    /// connection).
    pub conn_panics: AtomicU64,
}

impl RecoveryCounters {
    pub fn new() -> Self {
        RecoveryCounters::default()
    }

    /// (restarts, sheds, timeouts, malformed, conn_panics) snapshot.
    pub fn snapshot(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.restarts.load(Ordering::Relaxed),
            self.sheds.load(Ordering::Relaxed),
            self.timeouts.load(Ordering::Relaxed),
            self.malformed.load(Ordering::Relaxed),
            self.conn_panics.load(Ordering::Relaxed),
        )
    }
}

/// Accumulators behind one [`SnapshotWindow`] lock.
#[derive(Debug)]
struct WindowState {
    since: Instant,
    requests: u64,
    batches: u64,
    rows: u64,
    queue_ns: u64,
}

/// One consistent read of a [`SnapshotWindow`]: everything recorded
/// since the previous snapshot, plus the window's wall-clock span. All
/// derived figures divide **as f64**, so a window with fewer requests
/// than its divisor reports the true fraction instead of a silently
/// truncated 0 — and guard a zero denominator explicitly.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WindowSnapshot {
    pub secs: f64,
    pub requests: u64,
    pub batches: u64,
    pub rows: u64,
    pub queue_ns: u64,
}

impl WindowSnapshot {
    /// Requests per second over the window (0.0 for an instant window).
    pub fn rate_per_sec(&self) -> f64 {
        if self.secs <= 0.0 {
            0.0
        } else {
            self.requests as f64 / self.secs
        }
    }

    /// Mean batch occupancy (rows per dispatched batch) in the window.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.rows as f64 / self.batches as f64
        }
    }

    /// Mean queue latency in microseconds over the window.
    pub fn mean_queue_us(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.queue_ns as f64 / self.requests as f64 / 1e3
        }
    }
}

/// Windowed request telemetry with an atomic snapshot-and-reset.
///
/// Writers ([`record`](Self::record)) and the reader
/// ([`snapshot_and_reset`](Self::snapshot_and_reset)) share one mutex,
/// so a snapshot taken mid-flush observes each recorded flush exactly
/// once: every event lands in exactly one window, and summing window
/// counts over time equals the cumulative counters — no double-count,
/// no loss. (The cumulative per-variant counters stay lock-free
/// atomics; this lock is only taken once per batch flush and once per
/// `STATS` read, both far off the per-request path.)
#[derive(Debug)]
pub struct SnapshotWindow {
    state: Mutex<WindowState>,
}

impl SnapshotWindow {
    pub fn new() -> Self {
        SnapshotWindow {
            state: Mutex::new(WindowState {
                since: Instant::now(),
                requests: 0,
                batches: 0,
                rows: 0,
                queue_ns: 0,
            }),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, WindowState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Record one batch flush: `requests` jobs answered, `rows` of them
    /// dispatched in one batch, with `queue_ns` total queue time.
    pub fn record(&self, requests: u64, batches: u64, rows: u64, queue_ns: u64) {
        let mut s = self.lock();
        s.requests += requests;
        s.batches += batches;
        s.rows += rows;
        s.queue_ns += queue_ns;
    }

    /// Read the current window and atomically start the next one.
    pub fn snapshot_and_reset(&self) -> WindowSnapshot {
        self.snapshot_at(Instant::now())
    }

    /// [`snapshot_and_reset`](Self::snapshot_and_reset) with an explicit
    /// "now" so tests can pin window spans without sleeping.
    pub fn snapshot_at(&self, now: Instant) -> WindowSnapshot {
        let mut s = self.lock();
        let snap = WindowSnapshot {
            secs: now.saturating_duration_since(s.since).as_secs_f64(),
            requests: s.requests,
            batches: s.batches,
            rows: s.rows,
            queue_ns: s.queue_ns,
        };
        *s = WindowState { since: now, requests: 0, batches: 0, rows: 0, queue_ns: 0 };
        snap
    }
}

impl Default for SnapshotWindow {
    fn default() -> Self {
        Self::new()
    }
}

/// Types that can report the size of their live model state.
pub trait ModelFootprint {
    /// Approximate heap bytes held by the model (data structures that grow
    /// with the number of samples/tasks).
    fn model_bytes(&self) -> usize;
}

/// Current resident set size of this process in bytes (Linux), or None.
pub fn rss_bytes() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: usize = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Tracks the peak of a monotonically sampled quantity plus elapsed time.
#[derive(Debug)]
pub struct PeakTracker {
    start: Instant,
    peak: usize,
}

impl PeakTracker {
    pub fn new() -> Self {
        PeakTracker { start: Instant::now(), peak: 0 }
    }
    /// Record an observation; keeps the max.
    pub fn observe(&mut self, bytes: usize) {
        self.peak = self.peak.max(bytes);
    }
    pub fn peak_bytes(&self) -> usize {
        self.peak
    }
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Default for PeakTracker {
    fn default() -> Self {
        Self::new()
    }
}

/// Simple stopwatch for phase timing (sampling vs modeling vs optimizing).
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_counters_track_rate() {
        let c = HitCounters::new();
        assert_eq!(c.hit_rate(), 0.0);
        c.hit();
        c.hit();
        c.hit();
        c.miss();
        assert_eq!(c.hits(), 3);
        assert_eq!(c.misses(), 1);
        assert_eq!(c.total(), 4);
        assert!((c.hit_rate() - 0.75).abs() < 1e-12);
        c.reset();
        assert_eq!(c.total(), 0);
    }

    #[test]
    fn recovery_counters_snapshot_in_field_order() {
        let c = RecoveryCounters::new();
        assert_eq!(c.snapshot(), (0, 0, 0, 0, 0));
        c.restarts.fetch_add(1, Ordering::Relaxed);
        c.sheds.fetch_add(2, Ordering::Relaxed);
        c.timeouts.fetch_add(3, Ordering::Relaxed);
        c.malformed.fetch_add(4, Ordering::Relaxed);
        c.conn_panics.fetch_add(5, Ordering::Relaxed);
        assert_eq!(c.snapshot(), (1, 2, 3, 4, 5));
    }

    #[test]
    fn window_arithmetic_is_fractional_not_integer() {
        // Regression: a window with fewer requests than its divisor
        // (here 1 request over 2 seconds, 3 rows over 2 batches) must
        // report the true fraction, not an integer-division 0.
        let w = SnapshotWindow::new();
        let t0 = Instant::now();
        w.record(1, 2, 3, 1500);
        let snap = w.snapshot_at(t0 + std::time::Duration::from_secs(2));
        assert!(snap.secs >= 2.0);
        assert!((snap.rate_per_sec() - 1.0 / snap.secs).abs() < 1e-12);
        assert!(snap.rate_per_sec() > 0.0, "sub-1/sec rate must not truncate to 0");
        assert!((snap.mean_batch() - 1.5).abs() < 1e-12);
        assert!((snap.mean_queue_us() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn empty_window_reports_zeroes_not_nan() {
        let w = SnapshotWindow::new();
        let snap = w.snapshot_and_reset();
        assert_eq!(snap.requests, 0);
        assert_eq!(snap.rate_per_sec(), 0.0);
        assert_eq!(snap.mean_batch(), 0.0);
        assert_eq!(snap.mean_queue_us(), 0.0);
        // Degenerate zero-width window: rate guards the denominator.
        let zero = WindowSnapshot { secs: 0.0, requests: 5, batches: 1, rows: 5, queue_ns: 0 };
        assert_eq!(zero.rate_per_sec(), 0.0);
    }

    #[test]
    fn snapshot_resets_and_never_double_counts() {
        // Every recorded event must land in exactly one window, even
        // with snapshots racing the recorders: total across windows ==
        // total recorded.
        let w = std::sync::Arc::new(SnapshotWindow::new());
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut observed = WindowSnapshot { secs: 0.0, requests: 0, batches: 0, rows: 0, queue_ns: 0 };
        let writers: Vec<_> = (0..4)
            .map(|_| {
                let w = w.clone();
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        w.record(1, 1, 1, 10);
                    }
                })
            })
            .collect();
        let reader = {
            let (w, stop) = (w.clone(), stop.clone());
            std::thread::spawn(move || {
                let mut acc = (0u64, 0u64, 0u64, 0u64);
                while !stop.load(Ordering::Relaxed) {
                    let s = w.snapshot_and_reset();
                    acc.0 += s.requests;
                    acc.1 += s.batches;
                    acc.2 += s.rows;
                    acc.3 += s.queue_ns;
                }
                acc
            })
        };
        for t in writers {
            t.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        let acc = reader.join().unwrap();
        let last = w.snapshot_and_reset();
        observed.requests = acc.0 + last.requests;
        observed.batches = acc.1 + last.batches;
        observed.rows = acc.2 + last.rows;
        observed.queue_ns = acc.3 + last.queue_ns;
        assert_eq!(observed.requests, 40_000);
        assert_eq!(observed.batches, 40_000);
        assert_eq!(observed.rows, 40_000);
        assert_eq!(observed.queue_ns, 400_000);
    }

    #[test]
    fn rss_is_positive_on_linux() {
        let rss = rss_bytes().expect("linux /proc should exist");
        assert!(rss > 1 << 20, "rss={rss}"); // > 1 MiB
    }

    #[test]
    fn peak_tracker_keeps_max() {
        let mut t = PeakTracker::new();
        t.observe(10);
        t.observe(100);
        t.observe(50);
        assert_eq!(t.peak_bytes(), 100);
        assert!(t.elapsed_secs() >= 0.0);
    }

    #[test]
    fn stopwatch_advances() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.secs() >= 0.004);
    }
}
