//! Property-based tests over the core invariants (seeded random-input
//! sweeps — the in-tree analog of proptest, which is unavailable offline).
//!
//! Each property runs against many randomly generated spaces / datasets /
//! seeds; any failure prints the seed for reproduction.

use mlkaps::config::space::{ParamDef, ParamKind, ParamSpace};
use mlkaps::data::Dataset;
use mlkaps::dtree::cart::{Cart, CartParams, TaskKind};
use mlkaps::optimizer::nsga2::{Nsga2, Nsga2Params};
use mlkaps::sampling::hvs::Hvs;
use mlkaps::sampling::lhs::lhs_design;
use mlkaps::sampling::random::RandomSampler;
use mlkaps::sampling::{SampleCtx, Sampler};
use mlkaps::surrogate::gbdt::{Gbdt, GbdtParams};
use mlkaps::surrogate::Surrogate;
use mlkaps::util::json;
use mlkaps::util::rng::Rng;

/// Generate a random ParamSpace of 1..=6 mixed-kind dimensions.
fn random_space(rng: &mut Rng) -> ParamSpace {
    let d = 1 + rng.below(6);
    let params = (0..d)
        .map(|i| {
            let name = format!("p{i}");
            match rng.below(4) {
                0 => {
                    let lo = rng.uniform(-100.0, 100.0);
                    ParamDef::float(&name, lo, lo + rng.uniform(0.5, 200.0))
                }
                1 => {
                    let lo = rng.int_range(-50, 50);
                    ParamDef::int(&name, lo, lo + rng.int_range(1, 100))
                }
                2 => {
                    let k = 2 + rng.below(6);
                    let choices: Vec<String> =
                        (0..k).map(|c| format!("c{c}")).collect();
                    let refs: Vec<&str> = choices.iter().map(String::as_str).collect();
                    ParamDef::categorical(&name, &refs)
                }
                _ => ParamDef::boolean(&name),
            }
        })
        .collect();
    ParamSpace::new(params)
}

#[test]
fn prop_decode_always_lands_on_valid_values() {
    let mut rng = Rng::new(0xA11CE);
    for trial in 0..300 {
        let space = random_space(&mut rng);
        let unit: Vec<f64> = (0..space.dim()).map(|_| rng.f64()).collect();
        let v = space.decode(&unit);
        let snapped = space.snap(&v);
        assert_eq!(v, snapped, "trial {trial}: decode not snap-stable");
    }
}

#[test]
fn prop_encode_decode_identity_on_decoded_points() {
    let mut rng = Rng::new(0xB0B);
    for trial in 0..300 {
        let space = random_space(&mut rng);
        let unit: Vec<f64> = (0..space.dim()).map(|_| rng.f64()).collect();
        let v = space.decode(&unit);
        let v2 = space.decode(&space.encode(&v));
        assert_eq!(v, v2, "trial {trial}: decode∘encode not idempotent");
    }
}

#[test]
fn prop_grid_points_are_valid_and_unique_for_discrete_spaces() {
    let mut rng = Rng::new(0xC0DE);
    for trial in 0..50 {
        let space = random_space(&mut rng);
        let g = space.grid(3);
        assert_eq!(g.len(), 3usize.pow(space.dim() as u32), "trial {trial}");
        for p in &g {
            assert_eq!(*p, space.snap(p), "trial {trial}");
        }
    }
}

#[test]
fn prop_lhs_stratification_all_dims_all_sizes() {
    let mut rng = Rng::new(0xD1CE);
    for _ in 0..40 {
        let n = 2 + rng.below(200);
        let d = 1 + rng.below(8);
        let pts = lhs_design(n, d, &mut rng);
        for dim in 0..d {
            let mut strata: Vec<usize> =
                pts.iter().map(|p| ((p[dim] * n as f64) as usize).min(n - 1)).collect();
            strata.sort_unstable();
            assert_eq!(strata, (0..n).collect::<Vec<_>>(), "n={n} d={d} dim={dim}");
        }
    }
}

#[test]
fn prop_samplers_return_exact_count_in_unit_cube() {
    let mut rng = Rng::new(0xE66);
    for trial in 0..40 {
        let space = random_space(&mut rng);
        // Random history over the space.
        let mut hist = Dataset::new();
        for _ in 0..rng.below(300) {
            let u: Vec<f64> = (0..space.dim()).map(|_| rng.f64()).collect();
            let y = rng.uniform(0.0, 10.0);
            hist.push(u, y);
        }
        let n_inputs = 1.min(space.dim());
        let ctx = SampleCtx { space: &space, n_inputs, history: &hist };
        let want = 1 + rng.below(100);
        for sampler in [
            &mut RandomSampler as &mut dyn Sampler,
            &mut Hvs::hvs(),
            &mut Hvs::hvsr(),
        ] {
            let batch = sampler.next_batch(want, &ctx, &mut rng);
            assert_eq!(batch.len(), want, "trial {trial} {}", sampler.name());
            for p in &batch {
                assert_eq!(p.len(), space.dim());
                assert!(p.iter().all(|v| (0.0..=1.0).contains(v)),
                    "trial {trial} {} out of cube", sampler.name());
            }
        }
    }
}

#[test]
fn prop_gbdt_predictions_always_finite_and_within_target_hull() {
    let mut rng = Rng::new(0xF00D);
    for trial in 0..25 {
        let d = 1 + rng.below(5);
        let n = 20 + rng.below(400);
        let mut data = Dataset::new();
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for _ in 0..n {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-5.0, 5.0)).collect();
            let y = rng.uniform(-3.0, 3.0);
            lo = lo.min(y);
            hi = hi.max(y);
            data.push(x, y);
        }
        let mut m = Gbdt::new(GbdtParams { n_trees: 30, ..Default::default() });
        m.fit(&data);
        for _ in 0..50 {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-10.0, 10.0)).collect();
            let p = m.predict(&x);
            assert!(p.is_finite(), "trial {trial}");
            // Gradient boosting with shrinkage stays within a modest
            // expansion of the target hull.
            let span = (hi - lo).max(1e-9);
            assert!(
                p >= lo - span && p <= hi + span,
                "trial {trial}: prediction {p} far outside [{lo}, {hi}]"
            );
        }
    }
}

#[test]
fn prop_cart_classification_predicts_a_training_class() {
    let mut rng = Rng::new(0x9A9);
    for trial in 0..40 {
        let n = 10 + rng.below(200);
        let classes: Vec<f64> = (0..1 + rng.below(5)).map(|c| c as f64).collect();
        let x: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.f64(), rng.f64()]).collect();
        let y: Vec<f64> = (0..n).map(|_| *rng.choice(&classes)).collect();
        let mut t = Cart::new(CartParams {
            task: TaskKind::Classification,
            ..Default::default()
        });
        t.fit(&x, &y);
        for _ in 0..30 {
            let q = vec![rng.f64(), rng.f64()];
            let p = t.predict(&q);
            assert!(classes.contains(&p), "trial {trial}: class {p} not in training set");
        }
    }
}

#[test]
fn prop_nsga2_never_leaves_unit_cube_and_improves() {
    let mut rng = Rng::new(0xAB1E);
    for trial in 0..20 {
        let d = 1 + rng.below(6);
        let target: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
        let t2 = target.clone();
        let f = move |x: &[f64]| -> f64 {
            x.iter().zip(&t2).map(|(a, b)| (a - b) * (a - b)).sum()
        };
        let ga = Nsga2::new(Nsga2Params { pop_size: 16, generations: 15, ..Default::default() });
        let fr = &f;
        let obj = move |x: &[f64]| fr(x);
        let (best, val) = ga.minimize(d, &obj, &[], &mut rng);
        assert!(best.iter().all(|v| (0.0..=1.0).contains(v)), "trial {trial}");
        // Must beat the expected value of a random point (d/6 on average).
        assert!(val < d as f64 / 6.0, "trial {trial}: val {val} for dim {d}");
    }
}

#[test]
fn prop_json_roundtrip_random_documents() {
    fn random_value(rng: &mut Rng, depth: usize) -> json::Value {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => json::Value::Null,
            1 => json::Value::Bool(rng.bool(0.5)),
            2 => json::Value::Num((rng.uniform(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => json::Value::Str(format!("s{}-\"quote\"\n", rng.below(1000))),
            4 => json::Value::Arr(
                (0..rng.below(5)).map(|_| random_value(rng, depth + 1)).collect(),
            ),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.below(5) {
                    m.insert(format!("k{i}"), random_value(rng, depth + 1));
                }
                json::Value::Obj(m)
            }
        }
    }
    let mut rng = Rng::new(0x15EA5E);
    for trial in 0..200 {
        let v = random_value(&mut rng, 0);
        let compact = json::parse(&v.to_string());
        let pretty = json::parse(&v.to_pretty());
        assert_eq!(compact.as_ref().ok(), Some(&v), "trial {trial} compact");
        assert_eq!(pretty.as_ref().ok(), Some(&v), "trial {trial} pretty");
    }
}

#[test]
fn prop_hvs_constant_objective_degrades_gracefully() {
    // All-identical objectives -> zero variance everywhere -> sampler
    // must still return the requested batch (uniform fallback).
    let mut rng = Rng::new(0x5A5A);
    let space = ParamSpace::new(vec![
        ParamDef::float("a", 0.0, 1.0),
        ParamDef::float("b", 0.0, 1.0),
    ]);
    let mut hist = Dataset::new();
    for _ in 0..200 {
        hist.push(vec![rng.f64(), rng.f64()], 1.0);
    }
    let ctx = SampleCtx { space: &space, n_inputs: 1, history: &hist };
    let batch = Hvs::hvs().next_batch(64, &ctx, &mut rng);
    assert_eq!(batch.len(), 64);
}

#[test]
fn prop_pdgeqrf_reformulation_constraints_hold_everywhere() {
    use mlkaps::kernels::pdgeqrf_sim::{concretize, PdgeqrfSim, MAX_PER_NODE};
    use mlkaps::kernels::Kernel;
    let sim = PdgeqrfSim::new(0);
    let mut rng = Rng::new(0x7777);
    for _ in 0..2000 {
        let iu: Vec<f64> = (0..2).map(|_| rng.f64()).collect();
        let du: Vec<f64> = (0..4).map(|_| rng.f64()).collect();
        let input = sim.input_space().decode(&iu);
        let design = sim.design_space().decode(&du);
        let c = concretize(&input, &design);
        assert!(c.mb >= 1.0 && c.mb <= (input[0] / (8.0 * c.p)).max(1.0) + 0.5);
        assert!(c.npernode >= c.p && c.npernode <= MAX_PER_NODE);
        assert!(c.nb >= 1.0 && c.nb <= 16.0);
        let t = sim.eval_true(&input, &design);
        assert!(t.is_finite() && t > 0.0);
    }
}

#[test]
fn prop_serving_decide_matches_cart_predict_and_codegen_eval() {
    // Three independent evaluators of the same tree bundle — the
    // pointer-walk `Cart::predict`, the generated-code interpreter
    // `eval_like_generated`, and the flattened serving arena behind
    // `TreeBundle::decide` — must agree bit for bit on random fitted
    // trees and adversarial queries (NaN, out-of-domain, huge values).
    use mlkaps::dtree::codegen::eval_like_generated;
    use mlkaps::dtree::DesignTrees;
    use mlkaps::runtime::serving::TreeBundle;

    let mut rng = Rng::new(0x5E_BF1E);
    for trial in 0..20 {
        let d_in = 1 + rng.below(4);
        let input = ParamSpace::new(
            (0..d_in)
                .map(|i| ParamDef::float(&format!("x{i}"), -10.0, 10.0))
                .collect(),
        );
        let n_design = 1 + rng.below(3);
        let design = ParamSpace::new(
            (0..n_design)
                .map(|j| {
                    let name = format!("d{j}");
                    match rng.below(4) {
                        0 => ParamDef::int(&name, 1, 2 + rng.int_range(1, 60)),
                        1 => ParamDef::categorical(
                            &name,
                            &["a", "b", "c", "d"][..2 + rng.below(3)],
                        ),
                        2 => ParamDef::boolean(&name),
                        _ => ParamDef::float(&name, 0.0, 1.0 + rng.uniform(0.0, 9.0)),
                    }
                })
                .collect(),
        );
        let n = 30 + rng.below(200);
        let xs: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..d_in).map(|_| rng.uniform(-10.0, 10.0)).collect())
            .collect();
        let designs: Vec<Vec<f64>> = xs
            .iter()
            .map(|x| {
                let raw: Vec<f64> = (0..n_design)
                    .map(|j| x[0].abs() * (1.0 + j as f64) + x[x.len() - 1])
                    .collect();
                design.snap(&raw)
            })
            .collect();
        let model = DesignTrees::fit(&xs, &designs, &input, &design, 1 + rng.below(8));
        let bundle = TreeBundle::from_trees(model.clone()).unwrap();

        let mut probes: Vec<Vec<f64>> = Vec::new();
        let mut wants: Vec<Vec<f64>> = Vec::new();
        for _ in 0..40 {
            let q: Vec<f64> = (0..d_in)
                .map(|_| match rng.below(10) {
                    0 => f64::NAN,
                    1 => rng.uniform(-1e6, 1e6), // far out of domain
                    _ => rng.uniform(-12.0, 12.0),
                })
                .collect();
            let raw: Vec<f64> = model.trees.iter().map(|t| t.predict(&q)).collect();
            for (t, &r) in model.trees.iter().zip(&raw) {
                assert_eq!(
                    eval_like_generated(t, &q).to_bits(),
                    r.to_bits(),
                    "trial {trial}: codegen interpreter diverged on {q:?}"
                );
            }
            let want = model.design_space.snap(&raw);
            assert_eq!(model.predict(&q), want, "trial {trial}");
            assert_eq!(bundle.decide(&q), want, "trial {trial}: serving diverged on {q:?}");
            probes.push(q);
            wants.push(want);
        }
        for threads in [1usize, 3, 0] {
            assert_eq!(
                bundle.decide_batch(&probes, threads),
                wants,
                "trial {trial}: batch diverged at threads={threads}"
            );
        }
    }
}

#[test]
fn prop_param_space_json_roundtrip() {
    let mut rng = Rng::new(0x0DD_BA11);
    for trial in 0..200 {
        let space = random_space(&mut rng);
        let back = ParamSpace::from_json(&space.to_json()).unwrap();
        assert_eq!(back, space, "trial {trial}: value round-trip");
        // And through serialized text (what checkpoints actually store).
        let text = space.to_json().to_pretty();
        let back2 = ParamSpace::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back2, space, "trial {trial}: text round-trip");
    }
}

#[test]
fn prop_protocol_parsers_never_panic_or_overallocate_on_arbitrary_bytes() {
    // The daemon's framing auto-detection routes a connection by its
    // first byte (0x00 = binary length-prefixed, anything else = text
    // lines). Throw arbitrary byte soup at both parsers: they must
    // never panic, a successful frame can never exceed the bytes
    // actually supplied, and an absurd length announcement must be
    // rejected as Oversized *before* any payload allocation (a 4 GiB
    // prefix against a 10-byte stream returns instantly).
    use mlkaps::runtime::server::protocol::{
        read_frame, write_frame, FrameError, Request, MAX_FRAME,
    };

    let mut rng = Rng::new(0xFA11_0BAD);
    for trial in 0..2000 {
        let n = rng.below(64);
        let mut bytes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        match rng.below(4) {
            // Raw soup as generated.
            0 => {}
            // A plausible small-frame prefix (length may still exceed
            // what follows — a truncated frame).
            1 => {
                let mut b = (rng.below(48) as u32).to_be_bytes().to_vec();
                b.extend_from_slice(&bytes);
                bytes = b;
            }
            // A valid frame, then an absurd length announcement: a
            // length ≥ MAX_FRAME has a nonzero first byte, so only a
            // mid-stream prefix can reach the binary route's Oversized
            // rejection.
            2 => {
                let mut b = Vec::new();
                write_frame(&mut b, b"{\"op\":\"ping\"}").unwrap();
                let len = MAX_FRAME as u32 + rng.below(1 << 20) as u32;
                b.extend_from_slice(&len.to_be_bytes());
                b.extend_from_slice(&bytes);
                bytes = b;
            }
            // Valid JSON wrapped in a valid frame, to keep the happy
            // path in the mix.
            _ => {
                let mut b = Vec::new();
                write_frame(&mut b, b"{\"kernel\":\"k\",\"input\":[1,2]}").unwrap();
                b.extend_from_slice(&bytes);
                bytes = b;
            }
        }

        if bytes.first() == Some(&0x00) {
            // Binary route: drain frames until EOF or an error.
            let mut cursor = std::io::Cursor::new(bytes.clone());
            loop {
                match read_frame(&mut cursor) {
                    Ok(Some(payload)) => {
                        assert!(
                            payload.len() <= bytes.len(),
                            "trial {trial}: frame larger than the input"
                        );
                        if let Ok(text) = std::str::from_utf8(&payload) {
                            let _ = json::parse(text).map(|v| Request::from_json(&v));
                        }
                    }
                    Ok(None) => break,
                    Err(FrameError::Oversized(len)) => {
                        assert!(len >= MAX_FRAME, "trial {trial}: premature Oversized");
                        break;
                    }
                    Err(_) => break,
                }
            }
        }
        // Text route: every line (and the lossy whole) parses or errors,
        // never panics.
        let text = String::from_utf8_lossy(&bytes);
        let _ = Request::from_line(&text);
        for line in text.lines() {
            let _ = Request::from_line(line);
        }
    }

    // Building an oversized frame is refused symmetrically.
    let mut sink = Vec::new();
    assert!(matches!(
        write_frame(&mut sink, &vec![0u8; MAX_FRAME]),
        Err(FrameError::Oversized(_))
    ));
}

#[test]
fn prop_kind_cardinality_consistent_with_decode_range() {
    let mut rng = Rng::new(0x31337);
    for _ in 0..100 {
        let space = random_space(&mut rng);
        for p in &space.params {
            if let Some(card) = p.cardinality() {
                // Sample decode outputs; distinct values must not exceed
                // the declared cardinality.
                let mut seen = std::collections::BTreeSet::new();
                for i in 0..200 {
                    let u = i as f64 / 199.0;
                    seen.insert(p.decode(u).to_bits());
                }
                assert!(seen.len() as u64 <= card, "{:?}", p.kind);
                if card <= 200 {
                    assert_eq!(seen.len() as u64, card, "{:?}", p.kind);
                }
            }
            match &p.kind {
                ParamKind::Float { .. } => {}
                _ => assert!(p.cardinality().is_some()),
            }
        }
    }
}
