//! Property suite for the fused lockstep grid optimizer: advancing all
//! grid points' GAs together and scoring whole generations through one
//! giant (pre-binned) surrogate batch must be **bit-identical** to the
//! legacy per-point schedule — same designs, same predicted objectives,
//! at any thread count, under any shard split, and across a mid-shard
//! kill/resume of the checkpointed pipeline.
//!
//! Exactness (assert_eq on f64 bits, no epsilon) is the contract that
//! lets stage-3 checkpoints written by either engine resume
//! interchangeably and keeps every golden artifact unchanged.

use std::path::PathBuf;

use mlkaps::config::space::{ParamDef, ParamSpace};
use mlkaps::data::Dataset;
use mlkaps::kernels::toy_sum::ToySum;
use mlkaps::kernels::Kernel;
use mlkaps::optimizer::grid::{optimize_grid_shard, optimize_grid_shard_per_point};
use mlkaps::optimizer::nsga2::{Nsga2, Nsga2Params};
use mlkaps::pipeline::checkpoint::{copy_checkpoints, PipelineRun};
use mlkaps::pipeline::{MlkapsConfig, SamplerChoice};
use mlkaps::surrogate::forest::Traversal;
use mlkaps::surrogate::gbdt::{Gbdt, GbdtParams};
use mlkaps::surrogate::{LogSurrogate, Surrogate};
use mlkaps::util::rng::Rng;

/// Build a random tuning-shaped problem: input/design spaces with mixed
/// parameter kinds and a log-objective GBDT surrogate fit on noisy data
/// over the joint space — i.e. exactly what stage 3 consumes.
fn random_case(rng: &mut Rng) -> (ParamSpace, ParamSpace, LogSurrogate<Gbdt>) {
    let input = if rng.bool(0.5) {
        ParamSpace::new(vec![ParamDef::float("n", 64.0, 8192.0)])
    } else {
        ParamSpace::new(vec![
            ParamDef::float("n", 64.0, 8192.0),
            ParamDef::float("m", 64.0, 8192.0),
        ])
    };
    let mut design_params = vec![ParamDef::float("t", 0.0, 1.0)];
    if rng.bool(0.7) {
        design_params.push(ParamDef::int("nb", 1, 64));
    }
    if rng.bool(0.5) {
        design_params.push(ParamDef::categorical("variant", &["a", "b", "c"]));
    }
    let design = ParamSpace::new(design_params);

    let d = input.dim() + design.dim();
    let n = 150 + rng.below(150);
    let mut data = Dataset::with_capacity(n);
    for _ in 0..n {
        let x: Vec<f64> = (0..d).map(|_| rng.uniform(0.0, 8192.0)).collect();
        let y = 1.0
            + (x[0] * 1e-3).abs()
            + x.iter().skip(1).map(|v| (v * 0.7e-3).sin().abs()).sum::<f64>()
            + rng.uniform(0.0, 0.2);
        data.push(x, y);
    }
    let mut surrogate = LogSurrogate::new(Gbdt::new(GbdtParams {
        n_trees: 10 + rng.below(40),
        seed: rng.next_u64(),
        ..Default::default()
    }));
    surrogate.fit(&data);
    (input, design, surrogate)
}

#[test]
fn prop_fused_lockstep_equals_per_point_bit_for_bit() {
    let mut rng = Rng::new(0xF0_5ED);
    let mut prebinned_cases = 0;
    for trial in 0..8 {
        let (input, design, surrogate) = random_case(&mut rng);
        // Most fitted forests must actually exercise the pre-binned
        // fused path, not just the raw fallback.
        if surrogate.fused_forest().is_some_and(|cf| cf.bin_plan().is_some()) {
            prebinned_cases += 1;
        }
        let inputs = input.grid(4);
        let ga = Nsga2::new(Nsga2Params {
            pop_size: 8 + rng.below(12),
            generations: 4 + rng.below(8),
            ..Default::default()
        });
        let seed = rng.next_u64();
        let base = rng.below(100);
        let (d_ref, p_ref) = optimize_grid_shard_per_point(
            &surrogate, &design, &inputs, base, &ga, &[], 2, seed,
        );
        for threads in [1usize, 2, 8] {
            let (d, p) =
                optimize_grid_shard(&surrogate, &design, &inputs, base, &ga, &[], threads, seed);
            assert_eq!(d, d_ref, "trial {trial} threads {threads}: designs diverge");
            assert_eq!(
                p.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                p_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "trial {trial} threads {threads}: predictions diverge"
            );
        }

        // Shard-split invariance: computing the same global index range
        // in uneven pieces (what a mid-stage resume does) must
        // reassemble to the identical result.
        let cut = 1 + rng.below(inputs.len() - 1);
        let (mut d_split, d_tail) = {
            let (a, _) = optimize_grid_shard(
                &surrogate, &design, &inputs[..cut], base, &ga, &[], 4, seed,
            );
            let (b, _) = optimize_grid_shard(
                &surrogate, &design, &inputs[cut..], base + cut, &ga, &[], 1, seed,
            );
            (a, b)
        };
        d_split.extend(d_tail);
        assert_eq!(d_split, d_ref, "trial {trial}: shard split changed designs");
    }
    assert!(prebinned_cases >= 6, "only {prebinned_cases}/8 cases were prebinned");
}

#[test]
fn fused_lockstep_traversal_matches_blocked_and_per_point() {
    // One configuration pinned through the branch-free oblivious
    // lockstep layout explicitly: forcing the overlay on and off on the
    // same fitted surrogate must not move a single bit of the fused
    // stage-3 result — which itself must equal the per-point reference.
    let mut rng = Rng::new(0x0B_11_F05D);
    let mut armed_cases = 0;
    for trial in 0..4 {
        let (input, design, mut surrogate) = random_case(&mut rng);
        let inputs = input.grid(4);
        let ga = Nsga2::new(Nsga2Params {
            pop_size: 12,
            generations: 6,
            ..Default::default()
        });
        let seed = rng.next_u64();

        surrogate.inner.set_forest_traversal(Traversal::Blocked);
        assert!(surrogate.fused_forest().is_some_and(|cf| !cf.is_lockstep()));
        let (d_ref, p_ref) =
            optimize_grid_shard_per_point(&surrogate, &design, &inputs, 0, &ga, &[], 2, seed);
        let (d_blocked, p_blocked) =
            optimize_grid_shard(&surrogate, &design, &inputs, 0, &ga, &[], 2, seed);

        surrogate.inner.set_forest_traversal(Traversal::Lockstep);
        if surrogate.fused_forest().is_some_and(|cf| cf.is_lockstep()) {
            armed_cases += 1;
        }
        for threads in [1usize, 2, 8] {
            let (d_lock, p_lock) =
                optimize_grid_shard(&surrogate, &design, &inputs, 0, &ga, &[], threads, seed);
            assert_eq!(d_lock, d_ref, "trial {trial} threads {threads}: designs diverge");
            assert_eq!(
                p_lock.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                p_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "trial {trial} threads {threads}: predictions diverge"
            );
        }
        assert_eq!(d_blocked, d_ref, "trial {trial}: blocked designs diverge");
        assert_eq!(
            p_blocked.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            p_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "trial {trial}: blocked predictions diverge"
        );
    }
    assert!(
        armed_cases >= 3,
        "only {armed_cases}/4 cases armed the lockstep overlay"
    );
}

fn tiny_config(seed: u64) -> MlkapsConfig {
    MlkapsConfig {
        total_samples: 150,
        batch_size: 75,
        sampler: SamplerChoice::Lhs,
        gbdt: GbdtParams { n_trees: 25, ..Default::default() },
        ga: Nsga2Params { pop_size: 10, generations: 6, ..Default::default() },
        opt_grid: 4,
        tree_depth: 4,
        threads: 1,
        seed,
    }
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("mlkaps_fused_eq_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

#[test]
fn mid_shard_kill_resume_produces_byte_identical_stage3() {
    // An uninterrupted fused run vs one killed mid-stage-3 (only the
    // first shard survived) and resumed with a different thread count:
    // the assembled stage-3 artifact must be byte-identical, and both
    // must agree with the per-point reference on every grid design.
    let dir_full = tmp_dir("full");
    let dir_killed = tmp_dir("killed");

    let mut run_full = PipelineRun::new(tiny_config(60), dir_full.clone());
    run_full.shard_size = 6; // 4^2 grid -> shards of 6, 6, 4
    let uninterrupted = run_full.run(&ToySum::new(60)).unwrap();

    copy_checkpoints(&dir_full, &dir_killed).unwrap();
    // The "kill": assembled grid, trees, and all but the first shard
    // are lost mid-stage.
    for f in [
        "stage3_grid.json",
        "stage3_shard_0001.json",
        "stage3_shard_0002.json",
        "stage4_trees.json",
    ] {
        std::fs::remove_file(dir_killed.join(f)).unwrap();
    }
    let mut resumed_run = PipelineRun::new(
        MlkapsConfig { threads: 4, ..tiny_config(60) },
        dir_killed.clone(),
    );
    resumed_run.shard_size = 6;
    let resumed = resumed_run.run(&ToySum::new(60)).unwrap();

    assert_eq!(resumed.model.grid.designs, uninterrupted.model.grid.designs);
    assert_eq!(resumed.model.grid.predicted, uninterrupted.model.grid.predicted);
    let full_bytes = std::fs::read(dir_full.join("stage3_grid.json")).unwrap();
    let resumed_bytes = std::fs::read(dir_killed.join("stage3_grid.json")).unwrap();
    assert_eq!(full_bytes, resumed_bytes, "stage3 bytes diverge across resume");

    // Cross-check the fused engine against the per-point reference on
    // the very surrogate the pipeline fit (same GA settings; a fresh
    // seed is fine — equivalence must hold for any seed).
    let kernel = ToySum::new(60);
    let inputs = kernel.input_space().grid(4);
    let ga = Nsga2::new(tiny_config(60).ga);
    let (d_fused, p_fused) = optimize_grid_shard(
        &uninterrupted.model.surrogate,
        kernel.design_space(),
        &inputs,
        0,
        &ga,
        &[],
        2,
        4242,
    );
    let (d_ref, p_ref) = optimize_grid_shard_per_point(
        &uninterrupted.model.surrogate,
        kernel.design_space(),
        &inputs,
        0,
        &ga,
        &[],
        2,
        4242,
    );
    assert_eq!(d_fused, d_ref);
    assert_eq!(p_fused, p_ref);

    std::fs::remove_dir_all(&dir_full).ok();
    std::fs::remove_dir_all(&dir_killed).ok();
}
