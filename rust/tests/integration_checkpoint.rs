//! Integration tests for the checkpointed pipeline executor: serialization
//! round-trips for the stage artifacts, kill-and-resume fidelity, and
//! determinism of the sharded grid-optimization stage across thread
//! counts.
//!
//! Sampling uses `threads: 1` where runs must be comparable: simulator
//! measurement noise is drawn from a shared call counter, so parallel
//! evaluation order (legitimately) perturbs fresh sample values. Stages
//! 2-4 are deterministic for a fixed stage-1 checkpoint regardless of the
//! thread count — exactly what the cross-thread tests pin down.

use std::path::PathBuf;

use mlkaps::config::space::{ParamDef, ParamSpace};
use mlkaps::data::Dataset;
use mlkaps::dtree::DesignTrees;
use mlkaps::kernels::toy_sum::ToySum;
use mlkaps::optimizer::nsga2::Nsga2Params;
use mlkaps::pipeline::checkpoint::{copy_checkpoints, PipelineRun, Stage};
use mlkaps::pipeline::{Mlkaps, MlkapsConfig, SamplerChoice};
use mlkaps::surrogate::gbdt::{Gbdt, GbdtParams, Loss};
use mlkaps::surrogate::Surrogate;
use mlkaps::util::json::parse;
use mlkaps::util::rng::Rng;

fn config(seed: u64, threads: usize) -> MlkapsConfig {
    MlkapsConfig {
        total_samples: 200,
        batch_size: 100,
        sampler: SamplerChoice::Lhs,
        gbdt: GbdtParams { n_trees: 40, ..Default::default() },
        ga: Nsga2Params { pop_size: 12, generations: 8, ..Default::default() },
        opt_grid: 5,
        tree_depth: 4,
        threads,
        seed,
    }
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("mlkaps_ckpt_it_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Assert two tuned models are bit-identical in every checkpointed part.
fn assert_models_identical(
    a: &mlkaps::pipeline::TunedModel,
    b: &mlkaps::pipeline::TunedModel,
) {
    assert_eq!(a.dataset.x, b.dataset.x, "datasets diverge");
    assert_eq!(a.dataset.y, b.dataset.y, "objectives diverge");
    assert_eq!(a.grid.inputs, b.grid.inputs, "grid inputs diverge");
    assert_eq!(a.grid.designs, b.grid.designs, "grid designs diverge");
    assert_eq!(a.grid.predicted, b.grid.predicted, "grid predictions diverge");
    assert_eq!(
        a.trees.to_json().to_string(),
        b.trees.to_json().to_string(),
        "serialized trees diverge"
    );
    let mut rng = Rng::new(99);
    for _ in 0..50 {
        let input: Vec<f64> = vec![rng.uniform(64.0, 8192.0), rng.uniform(64.0, 8192.0)];
        assert_eq!(a.predict(&input), b.predict(&input), "{input:?}");
        let mut x = input.clone();
        x.push(rng.uniform(1.0, 64.0));
        assert_eq!(a.surrogate.predict(&x), b.surrogate.predict(&x), "{x:?}");
    }
}

#[test]
fn run_killed_after_surrogate_stage_resumes_bit_identical() {
    let kernel = ToySum::new(50);
    let dir_full = tmp_dir("full");
    let dir_killed = tmp_dir("killed");

    // Uninterrupted run.
    let full = PipelineRun::new(config(50, 1), dir_full.clone());
    let uninterrupted = full.run(&kernel).unwrap();

    // "Killed" run: the process dies right after the surrogate stage...
    let kernel2 = ToySum::new(50);
    let killed = PipelineRun::new(config(50, 1), dir_killed.clone());
    let partial = killed.run_prefix(&kernel2, Stage::Surrogate).unwrap();
    assert_eq!(partial.len(), 2, "only the first two stages ran");
    assert!(killed.load_model().is_err(), "model must not exist yet");

    // ...and a fresh process resumes it to completion.
    let kernel3 = ToySum::new(50);
    let resumed = killed.run(&kernel3).unwrap();
    assert!(resumed.stages[0].loaded, "sampling must be resumed, not re-run");
    assert!(resumed.stages[1].loaded, "surrogate must be resumed, not re-fit");
    assert!(!resumed.stages[2].loaded, "grid opt was never computed");
    assert!(!resumed.stages[3].loaded, "trees were never computed");

    assert_models_identical(&uninterrupted.model, &resumed.model);

    std::fs::remove_dir_all(&dir_full).ok();
    std::fs::remove_dir_all(&dir_killed).ok();
}

#[test]
fn sharded_grid_stage_is_deterministic_across_thread_counts() {
    let kernel = ToySum::new(51);
    let dir_a = tmp_dir("threads_a");
    let dir_b = tmp_dir("threads_b");

    // Sample + fit once (single-threaded), then share the checkpoints so
    // both runs optimize the identical surrogate.
    let seeded = PipelineRun::new(config(51, 1), dir_a.clone());
    seeded.run_prefix(&kernel, Stage::Surrogate).unwrap();
    copy_checkpoints(&dir_a, &dir_b).unwrap();

    // Resume A with 1 thread and default shards; resume B with 4 threads
    // and deliberately tiny shards (5^2 = 25 grid points -> 4 shards).
    let kernel_a = ToySum::new(51);
    let run_a = PipelineRun::new(config(51, 1), dir_a.clone());
    let out_a = run_a.run(&kernel_a).unwrap();

    let kernel_b = ToySum::new(51);
    let mut run_b = PipelineRun::new(config(51, 4), dir_b.clone());
    run_b.shard_size = 7;
    let out_b = run_b.run(&kernel_b).unwrap();
    assert!(out_b.stages[0].loaded && out_b.stages[1].loaded);

    assert_models_identical(&out_a.model, &out_b.model);

    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
}

#[test]
fn checkpointed_run_matches_plain_tune() {
    // The checkpointed executor is a refactoring of Mlkaps::tune, not a
    // different algorithm: same config + seed must give the same designs.
    let kernel = ToySum::new(52);
    let dir = tmp_dir("plain");
    let plain = Mlkaps::new(config(52, 1)).tune(&kernel);

    let kernel2 = ToySum::new(52);
    let ckpt = PipelineRun::new(config(52, 1), dir.clone()).run(&kernel2).unwrap();

    assert_eq!(plain.dataset.y, ckpt.model.dataset.y);
    assert_eq!(plain.grid.designs, ckpt.model.grid.designs);
    assert_eq!(
        plain.trees.to_json().to_string(),
        ckpt.model.trees.to_json().to_string()
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn interrupted_grid_stage_reuses_completed_shards() {
    let kernel = ToySum::new(53);
    let dir = tmp_dir("shards");

    let mut run = PipelineRun::new(config(53, 1), dir.clone());
    run.shard_size = 7;
    run.run_prefix(&kernel, Stage::GridOptimize).unwrap();

    // Simulate a crash that lost the assembled grid and the last shard but
    // kept the earlier shard checkpoints.
    std::fs::remove_file(dir.join("stage3_grid.json")).unwrap();
    std::fs::remove_file(dir.join("stage3_shard_0003.json")).unwrap();
    assert!(dir.join("stage3_shard_0000.json").exists());

    let kernel2 = ToySum::new(53);
    let resumed = run.run(&kernel2).unwrap();
    // The stage counts as computed (one shard was missing), yet completed
    // shards were reused and the result is complete and well-formed.
    assert!(!resumed.stages[2].loaded);
    assert_eq!(resumed.model.grid.designs.len(), 25);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn prop_gbdt_checkpoint_roundtrip_predicts_identically() {
    let mut rng = Rng::new(0xC0C0);
    for trial in 0..20 {
        let d = 1 + rng.below(4);
        let n = 30 + rng.below(300);
        let mut data = Dataset::new();
        for _ in 0..n {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-5.0, 5.0)).collect();
            let y = x.iter().sum::<f64>() + rng.normal();
            data.push(x, y);
        }
        let params = GbdtParams {
            n_trees: 5 + rng.below(40),
            max_leaves: 4 + rng.below(28),
            bagging_fraction: if rng.bool(0.5) { 0.8 } else { 1.0 },
            feature_fraction: if rng.bool(0.5) { 0.7 } else { 1.0 },
            loss: if rng.bool(0.5) { Loss::L1 } else { Loss::L2 },
            seed: rng.next_u64(),
            ..Default::default()
        };
        let mut cat = vec![false; d];
        if rng.bool(0.3) {
            cat[0] = true;
        }
        let mut m = Gbdt::with_mask(params, cat);
        m.fit(&data);
        let text = m.to_json().to_string();
        let back = Gbdt::from_json(&parse(&text).unwrap()).unwrap();
        for _ in 0..30 {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-6.0, 6.0)).collect();
            assert_eq!(m.predict(&x), back.predict(&x), "trial {trial}: {x:?}");
        }
    }
}

#[test]
fn prop_design_trees_checkpoint_roundtrip_predicts_identically() {
    let mut rng = Rng::new(0xDEED);
    for trial in 0..20 {
        let input = ParamSpace::new(vec![
            ParamDef::float("n", 100.0, 5000.0),
            ParamDef::float("m", 100.0, 5000.0),
        ]);
        let design = ParamSpace::new(vec![
            ParamDef::int("threads", 1, 64),
            ParamDef::categorical("variant", &["a", "b", "c"]),
            ParamDef::boolean("flag"),
        ]);
        let inputs = input.grid(2 + rng.below(6));
        let designs: Vec<Vec<f64>> = inputs
            .iter()
            .map(|_| {
                vec![
                    rng.int_range(1, 64) as f64,
                    rng.below(3) as f64,
                    rng.below(2) as f64,
                ]
            })
            .collect();
        let depth = 2 + rng.below(6);
        let model = DesignTrees::fit(&inputs, &designs, &input, &design, depth);
        let text = model.to_json().to_string();
        let back = DesignTrees::from_json(&parse(&text).unwrap()).unwrap();
        for _ in 0..40 {
            let q = vec![rng.uniform(100.0, 5000.0), rng.uniform(100.0, 5000.0)];
            assert_eq!(model.predict(&q), back.predict(&q), "trial {trial}: {q:?}");
        }
    }
}
