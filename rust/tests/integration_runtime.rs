//! Integration tests for the real three-layer path: AOT artifacts →
//! PJRT runtime → PallasLu kernel → MLKAPS pipeline. Skipped (with a
//! message) when `make artifacts` has not been run — i.e. when
//! `artifacts/manifest.json` + `artifacts/*.hlo.txt` from
//! `python/compile/aot.py` are absent — or when this build carries the
//! stub runtime (`pjrt` feature disabled).

use std::path::PathBuf;
use std::sync::Arc;

use mlkaps::kernels::pallas_lu::PallasLu;
use mlkaps::kernels::Kernel;
use mlkaps::optimizer::nsga2::Nsga2Params;
use mlkaps::pipeline::{Mlkaps, MlkapsConfig, SamplerChoice};
use mlkaps::runtime::{diag_dominant_matrix, LuRuntime};
use mlkaps::surrogate::gbdt::GbdtParams;

fn runtime() -> Option<Arc<LuRuntime>> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    match LuRuntime::new(dir) {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("skipping: {e}");
            None
        }
    }
}

#[test]
fn lu_numerics_match_across_all_n64_variants() {
    let Some(rt) = runtime() else { return };
    let n = 64;
    let a = diag_dominant_matrix(n, 11);
    let variants: Vec<_> = rt.manifest.for_size(n).into_iter().cloned().collect();
    assert!(variants.len() >= 3);
    let base = rt.run_lu(n, variants[0].block, variants[0].tile, &a).unwrap();
    for v in &variants[1..] {
        let out = rt.run_lu(n, v.block, v.tile, &a).unwrap();
        let max_diff = base
            .iter()
            .zip(&out)
            .map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max);
        assert!(
            max_diff < 5e-2,
            "variant b={} t={} diverges: {max_diff}",
            v.block,
            v.tile
        );
    }
}

#[test]
fn pipeline_tunes_real_kernel_from_real_measurements() {
    let Some(rt) = runtime() else { return };
    let mut kernel = PallasLu::new(rt.clone());
    kernel.reps = 1;
    let model = Mlkaps::new(MlkapsConfig {
        total_samples: 40,
        batch_size: 10,
        sampler: SamplerChoice::Lhs,
        gbdt: GbdtParams { n_trees: 30, ..Default::default() },
        ga: Nsga2Params { pop_size: 8, generations: 6, ..Default::default() },
        opt_grid: 4,
        tree_depth: 3,
        threads: 1,
        seed: 1,
    })
    .tune(&kernel);
    assert_eq!(model.stats.samples, 40);
    // Every prediction must resolve to an existing artifact.
    for si in 0..rt.manifest.sizes().len() {
        let d = model.predict(&[si as f64]);
        let (n, b, t) = kernel.variant_for(&[si as f64], &d);
        assert!(rt.manifest.find(n, b, t).is_some());
    }
}

#[test]
fn manifest_static_costs_are_consistent() {
    let Some(rt) = runtime() else { return };
    for v in &rt.manifest.variants {
        // flops = 2/3 n^3 (rounded by the Python side).
        let expect = 2.0 * (v.n as f64).powi(3) / 3.0;
        assert!((v.flops - expect).abs() / expect < 1e-4, "{:?}", v.path); // Python rounds
        // MXU utilization grows with tile size.
        assert!(v.mxu_utilization > 0.0 && v.mxu_utilization <= 1.0);
    }
    // Bigger tiles -> bigger VMEM footprint.
    let f = |b: usize, t: usize| {
        rt.manifest
            .find(64, b, t)
            .map(|v| v.vmem_bytes)
            .unwrap_or(0)
    };
    if f(16, 16) > 0 && f(32, 32) > 0 {
        assert!(f(32, 32) > f(16, 16));
    }
}
