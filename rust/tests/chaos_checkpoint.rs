//! Chaos suite for the checkpoint pipeline: inject a deterministic
//! fault at every `checkpoint.*` / `serving.load` failpoint site, let
//! the run die, then resume with the faults disarmed and prove the
//! recovered directory is **byte-for-byte identical** to a run that
//! never faulted — including a full `load_tree_artifact` chain
//! verification on the recovered directory.
//!
//! Failpoints are process-global, so every test serializes on one
//! mutex; the suite lives in its own test binary so it never races the
//! integration tests.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Mutex;

use mlkaps::kernels::toy_sum::ToySum;
use mlkaps::optimizer::nsga2::Nsga2Params;
use mlkaps::pipeline::checkpoint::{load_tree_artifact, read_fingerprint, PipelineRun};
use mlkaps::pipeline::{MlkapsConfig, SamplerChoice};
use mlkaps::runtime::serving::TreeBundle;
use mlkaps::surrogate::gbdt::GbdtParams;
use mlkaps::util::failpoint::{self, sites};

/// Failpoint state is process-global: tests take this before arming.
/// Poison-tolerant so one failed test doesn't wedge the rest.
static GATE: Mutex<()> = Mutex::new(());

fn gate() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// One fixed seed everywhere: every dir in this suite must converge to
/// the same bytes, faulted or not.
const SEED: u64 = 77;

fn config() -> MlkapsConfig {
    MlkapsConfig {
        total_samples: 120,
        batch_size: 60,
        sampler: SamplerChoice::Lhs,
        gbdt: GbdtParams { n_trees: 20, ..Default::default() },
        ga: Nsga2Params { pop_size: 8, generations: 5, ..Default::default() },
        opt_grid: 4,
        tree_depth: 4,
        threads: 1,
        seed: SEED,
    }
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("mlkaps_chaos_ckpt_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn run(dir: &PathBuf) -> Result<(), String> {
    PipelineRun::new(config(), dir.clone()).run(&ToySum::new(SEED)).map(|_| ())
}

/// Every regular file in the checkpoint directory, name → bytes. Also
/// catches leftovers a resume should have consumed (e.g. `.tmp` files).
fn snapshot(dir: &PathBuf) -> BTreeMap<String, Vec<u8>> {
    let mut files = BTreeMap::new();
    for entry in std::fs::read_dir(dir).expect("checkpoint dir readable").flatten() {
        if entry.file_type().map(|t| t.is_file()).unwrap_or(false) {
            files.insert(
                entry.file_name().to_string_lossy().into_owned(),
                std::fs::read(entry.path()).expect("checkpoint file readable"),
            );
        }
    }
    files
}

fn assert_identical(
    got: &BTreeMap<String, Vec<u8>>,
    want: &BTreeMap<String, Vec<u8>>,
    ctx: &str,
) {
    assert_eq!(
        got.keys().collect::<Vec<_>>(),
        want.keys().collect::<Vec<_>>(),
        "{ctx}: recovered directory holds a different file set"
    );
    for (name, bytes) in want {
        assert!(got[name] == *bytes, "{ctx}: {name} differs from the unfaulted run");
    }
}

/// Tentpole acceptance: for each write-path site (write / fsync /
/// commit), inject at the first and at a mid-pipeline artifact, watch
/// the run die with the injected error, resume disarmed, and require
/// byte-identical artifacts plus a passing chain verification.
#[test]
fn write_path_faults_resume_to_byte_identical_artifacts() {
    let _g = gate();
    let reference = tmp("ref");
    run(&reference).expect("unfaulted reference run");
    let want = snapshot(&reference);
    assert!(want.len() >= 5, "reference run wrote {} files", want.len());

    for site in [sites::CHECKPOINT_WRITE, sites::CHECKPOINT_FSYNC, sites::CHECKPOINT_COMMIT] {
        // hit 0 = the meta file, hit 3 = a stage-3 shard mid-pipeline.
        for nth in [0u64, 3] {
            let dir = tmp(&format!("{}_{nth}", site.replace('.', "_")));
            {
                let _armed = failpoint::arm_scoped(&format!("{site}=err@{nth}")).unwrap();
                let err = run(&dir).expect_err("the faulted run must die");
                assert!(err.contains("injected"), "{site}@{nth}: unexpected error: {err}");
                assert!(failpoint::hits(site) >= nth + 1, "{site} never reached hit {nth}");
            }
            run(&dir).unwrap_or_else(|e| panic!("resume after {site}@{nth} failed: {e}"));
            assert_identical(&snapshot(&dir), &want, &format!("{site}@{nth}"));
            load_tree_artifact(&dir)
                .unwrap_or_else(|e| panic!("chain verification after {site}@{nth}: {e}"));
            std::fs::remove_dir_all(&dir).ok();
        }
    }
    std::fs::remove_dir_all(&reference).ok();
}

/// Read and verify faults on a *completed* directory: a one-shot fault
/// silently recomputes the affected stage (the recovery path IS the
/// normal "checkpoint missing" path), an every-hit fault kills the run
/// at the reload-after-write — and in both cases the directory
/// converges back to the unfaulted bytes.
#[test]
fn read_and_verify_faults_recompute_to_byte_identical_artifacts() {
    let _g = gate();
    let dir = tmp("read_verify");
    run(&dir).expect("unfaulted reference run");
    let want = snapshot(&dir);

    // One-shot read fault (hit 0 = meta, hit 1 = stage1): stage1 is
    // treated as unreadable and recomputed; the run still succeeds and
    // the rewritten artifact is bit-identical, so the downstream
    // upstream-hash chain stays valid and stages 2-4 load.
    {
        let _armed = failpoint::arm_scoped("checkpoint.read=err@1").unwrap();
        run(&dir).expect("a one-shot read fault must be absorbed by recompute");
    }
    assert_identical(&snapshot(&dir), &want, "checkpoint.read=err@1");

    // One-shot verify fault: the stage-2 envelope is treated as stale,
    // the surrogate recomputes, and the reload's verify (next hit)
    // passes.
    {
        let _armed = failpoint::arm_scoped("checkpoint.verify=err@0").unwrap();
        run(&dir).expect("a one-shot verify fault must be absorbed by recompute");
    }
    assert_identical(&snapshot(&dir), &want, "checkpoint.verify=err@0");

    // Every-hit faults fail the reload-after-write hard; a disarmed
    // resume converges.
    for spec in ["checkpoint.read=err", "checkpoint.verify=err"] {
        {
            let _armed = failpoint::arm_scoped(spec).unwrap();
            let err = run(&dir).expect_err("an every-hit fault must kill the run");
            assert!(err.contains("checkpoint") || err.contains("envelope"), "{spec}: {err}");
        }
        run(&dir).unwrap_or_else(|e| panic!("resume after {spec} failed: {e}"));
        assert_identical(&snapshot(&dir), &want, spec);
    }

    load_tree_artifact(&dir).expect("chain verifies after every fault scenario");
    std::fs::remove_dir_all(&dir).ok();
}

/// `serving.load` fault: the chain-verified serving load fails loudly
/// (no partial bundle), and a disarmed retry loads a bundle whose
/// fingerprint agrees with the cheap meta poll.
#[test]
fn serving_load_fault_fails_cleanly_then_loads() {
    let _g = gate();
    let dir = tmp("serving_load");
    run(&dir).expect("unfaulted run");

    {
        let _armed = failpoint::arm_scoped("serving.load=err").unwrap();
        let err = TreeBundle::load_checkpoint_dir(&dir)
            .expect_err("an injected load fault must surface");
        assert!(err.contains("injected"), "{err}");
    }

    let bundle = TreeBundle::load_checkpoint_dir(&dir).expect("disarmed load succeeds");
    assert_eq!(
        bundle.fingerprint().map(str::to_string),
        Some(read_fingerprint(&dir).unwrap()),
        "loaded bundle fingerprint must agree with the meta poll"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Probability-triggered faults with a fixed seed are deterministic:
/// two armed runs against fresh dirs fail (or not) identically, which
/// is what makes `MLKAPS_FAILPOINTS=...=err@0.05` reproducible in CI.
#[test]
fn probability_faults_are_deterministic_under_a_fixed_seed() {
    let _g = gate();
    let outcome = |dir: &PathBuf| -> Result<(), String> {
        failpoint::arm_with_seed("checkpoint.write=err@0.3", 0xDECAF).unwrap();
        let r = run(dir);
        failpoint::disarm();
        r
    };
    let a_dir = tmp("prob_a");
    let b_dir = tmp("prob_b");
    let a = outcome(&a_dir);
    let b = outcome(&b_dir);
    assert_eq!(a.is_ok(), b.is_ok(), "same seed, same spec ⇒ same fate");
    assert_eq!(a.err(), b.err(), "and the same error text");
    // Whatever happened, a disarmed resume always converges.
    run(&a_dir).expect("resume a");
    run(&b_dir).expect("resume b");
    assert_identical(&snapshot(&a_dir), &snapshot(&b_dir), "prob resume");
    std::fs::remove_dir_all(&a_dir).ok();
    std::fs::remove_dir_all(&b_dir).ok();
}
