//! Chaos tests for the serving fleet (`mlkaps fleet`): real child
//! processes (the compiled `mlkaps` binary), real sockets, deterministic
//! faults.
//!
//! What must hold:
//!
//! * SIGKILL of a child under live traffic produces **zero wrong
//!   answers** — clients may see a dropped connection (they reconnect
//!   and retry), but every answer that arrives is bit-identical to the
//!   in-process reference — and the supervisor restarts the child
//!   within its backoff budget.
//! * A crash-looping child trips the circuit breaker and is parked as
//!   `degraded` while its siblings keep serving correct answers.
//! * A rolling redeploy under live traffic serves both checkpoint
//!   epochs (old fingerprint, then new) with zero requests answered
//!   wrongly and the whole fleet converging on the new fingerprint.
//! * Injected `fleet.spawn` / `fleet.health` faults produce the
//!   designed degradations (parked fleet; kill-and-restart), not hangs.
//!
//! Failpoints are process-global, so every test here serializes on one
//! gate mutex (the children are separate processes and never see the
//! test process's failpoints — only the supervisor does).

use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use mlkaps::kernels::toy_sum::ToySum;
use mlkaps::optimizer::nsga2::Nsga2Params;
use mlkaps::pipeline::checkpoint::{copy_checkpoints, PipelineRun};
use mlkaps::pipeline::{MlkapsConfig, SamplerChoice};
use mlkaps::runtime::fleet::{ChildState, Fleet, FleetConfig};
use mlkaps::runtime::server::client::ServedClient;
use mlkaps::runtime::serving::TreeBundle;
use mlkaps::surrogate::gbdt::GbdtParams;
use mlkaps::util::failpoint;
use mlkaps::util::rng::Rng;

static GATE: Mutex<()> = Mutex::new(());

fn gate() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn config(seed: u64) -> MlkapsConfig {
    MlkapsConfig {
        total_samples: 120,
        batch_size: 60,
        sampler: SamplerChoice::Lhs,
        gbdt: GbdtParams { n_trees: 20, ..Default::default() },
        ga: Nsga2Params { pop_size: 8, generations: 5, ..Default::default() },
        opt_grid: 4,
        tree_depth: 4,
        threads: 1,
        seed,
    }
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("mlkaps_fleet_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Tune toy-sum with `seed` into `dir`, returning the serving bundle.
fn tune_into(dir: &PathBuf, seed: u64) -> TreeBundle {
    PipelineRun::new(config(seed), dir.clone()).run(&ToySum::new(seed)).unwrap();
    TreeBundle::load_checkpoint_dir(dir).unwrap()
}

/// Reserve an ephemeral port for the shared fleet address: bind :0,
/// read the port, release it. (The fleet children must all be told one
/// concrete port — `SO_REUSEPORT` can't balance port 0.)
fn free_port() -> u16 {
    let l = TcpListener::bind("127.0.0.1:0").unwrap();
    l.local_addr().unwrap().port()
}

/// Test-sized fleet over a tuned checkpoint dir: fast probes, fast
/// backoff, the compiled `mlkaps` binary as the child image.
fn fleet_config(addr: &str, children: usize, dir: &PathBuf, tag: &str) -> FleetConfig {
    let mut cfg = FleetConfig::new(addr, children);
    cfg.binary = PathBuf::from(env!("CARGO_BIN_EXE_mlkaps"));
    cfg.control_dir = tmp_dir(&format!("{tag}_ctl"));
    cfg.child_args =
        vec!["--dir".into(), dir.display().to_string(), "--batch-window-us".into(), "1000".into()];
    cfg.probe_interval = Duration::from_millis(50);
    cfg.probe_timeout = Duration::from_millis(500);
    cfg.backoff_start = Duration::from_millis(50);
    cfg.backoff_cap = Duration::from_millis(500);
    cfg.redeploy_poll = Duration::from_millis(100);
    cfg.drain_timeout = Duration::from_secs(5);
    cfg
}

/// Decide `q` against the fleet, reconnecting and retrying on transport
/// errors (a killed or draining child drops its connections; the
/// reconnect lands on a live sibling). Panics if retries never land —
/// a request must not be droppable outright.
fn decide_with_retry(
    client: &mut ServedClient,
    addr: &str,
    q: &[f64],
) -> mlkaps::runtime::server::client::Decision {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        match client.decide("toy-sum", q, None) {
            Ok(d) => return d,
            Err(e) => {
                assert!(
                    Instant::now() < deadline,
                    "request {q:?} unanswerable for 30s: {e}"
                );
                *client = ServedClient::connect_str_with_retry(addr, Duration::from_secs(10))
                    .expect("reconnect to fleet");
            }
        }
    }
}

fn wait_for<F: FnMut() -> bool>(what: &str, timeout: Duration, mut f: F) {
    let deadline = Instant::now() + timeout;
    while !f() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn sigkill_under_load_serves_zero_wrong_answers_and_restarts_in_budget() {
    let _g = gate();
    let dir = tmp_dir("sigkill");
    let reference = Arc::new(tune_into(&dir, 70));

    let addr = format!("127.0.0.1:{}", free_port());
    let fleet = Fleet::start(fleet_config(&addr, 3, &dir, "sigkill")).unwrap();
    fleet.wait_ready(Duration::from_secs(60)).unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..3usize {
            let (stop, reference, addr) = (stop.clone(), reference.clone(), addr.clone());
            handles.push(scope.spawn(move || {
                let mut client =
                    ServedClient::connect_str_with_retry(&addr, Duration::from_secs(10))
                        .unwrap();
                let mut rng = Rng::new(3000 + t as u64);
                let mut answered = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let q = vec![rng.uniform(64.0, 8192.0), rng.uniform(64.0, 8192.0)];
                    let d = decide_with_retry(&mut client, &addr, &q);
                    // The invariant: an answer may be delayed by the
                    // kill, never wrong.
                    assert_eq!(d.values, reference.decide(&q), "wrong answer for {q:?}");
                    answered += 1;
                }
                answered
            }));
        }

        // Let traffic flow, then SIGKILL one child mid-stream.
        std::thread::sleep(Duration::from_millis(300));
        let victim = fleet.kill_child(1).expect("kill child 1");

        // Restart budget: first backoff is 50ms; boot is a checkpoint
        // load. Well under 15s even on a loaded CI runner.
        wait_for("child 1 restart", Duration::from_secs(15), || {
            fleet.children().iter().any(|c| {
                c.slot == 1
                    && c.state == ChildState::Running
                    && c.pid.is_some_and(|p| p != victim)
            })
        });
        std::thread::sleep(Duration::from_millis(200));
        stop.store(true, Ordering::Relaxed);

        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total > 0, "no traffic was served");
    });

    let restarted = fleet.children().iter().find(|c| c.slot == 1).unwrap().restarts;
    assert!(restarted >= 1, "supervisor never counted the restart");
    drop(fleet);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn crash_looping_child_is_parked_while_siblings_keep_serving() {
    let _g = gate();
    let dir = tmp_dir("crashloop");
    let reference = tune_into(&dir, 71);

    let addr = format!("127.0.0.1:{}", free_port());
    let mut cfg = fleet_config(&addr, 3, &dir, "crashloop");
    cfg.crash_k = 3;
    cfg.crash_window = Duration::from_secs(60);
    let fleet = Fleet::start(cfg).unwrap();
    fleet.wait_ready(Duration::from_secs(60)).unwrap();

    // Kill slot 0 every time it comes back: three deaths inside the
    // window trip the breaker.
    for round in 0..3 {
        let pid = loop {
            match fleet.kill_child(0) {
                Ok(pid) => break pid,
                // Between death and respawn there is no child to kill.
                Err(_) => std::thread::sleep(Duration::from_millis(20)),
            }
        };
        wait_for(
            &format!("death {round} of pid {pid} to register"),
            Duration::from_secs(15),
            || {
                fleet.children().iter().any(|c| {
                    c.slot == 0
                        && (c.state == ChildState::Degraded
                            || c.pid.map_or(true, |p| p != pid))
                })
            },
        );
    }
    wait_for("slot 0 to be parked as degraded", Duration::from_secs(15), || {
        fleet.children().iter().any(|c| c.slot == 0 && c.state == ChildState::Degraded)
    });

    // Siblings answer, correctly, with slot 0 parked.
    let mut client =
        ServedClient::connect_str_with_retry(&addr, Duration::from_secs(10)).unwrap();
    let mut rng = Rng::new(4000);
    for _ in 0..50 {
        let q = vec![rng.uniform(64.0, 8192.0), rng.uniform(64.0, 8192.0)];
        let d = decide_with_retry(&mut client, &addr, &q);
        assert_eq!(d.values, reference.decide(&q), "degraded sibling poisoned {q:?}");
    }
    let children = fleet.children();
    assert_eq!(
        children.iter().filter(|c| c.state == ChildState::Running).count(),
        2,
        "{children:?}"
    );

    // The aggregated fleet STATS reflects the parked child.
    let stats = fleet.stats();
    let agg = stats.get("fleet").unwrap();
    use mlkaps::util::json::Value;
    assert_eq!(agg.get("degraded").and_then(Value::as_f64), Some(1.0));
    assert_eq!(agg.get("running").and_then(Value::as_f64), Some(2.0));
    assert!(
        agg.get("kernels")
            .and_then(|k| k.get("toy-sum"))
            .and_then(|k| k.get("requests"))
            .and_then(Value::as_f64)
            .unwrap_or(0.0)
            >= 50.0
    );

    drop(fleet);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn rolling_redeploy_under_traffic_serves_both_epochs_with_no_wrong_answers() {
    let _g = gate();
    let staging_a = tmp_dir("roll_a");
    let staging_b = tmp_dir("roll_b");
    let watch = tmp_dir("roll_watch");

    let bundle_a = Arc::new(tune_into(&staging_a, 80));
    let bundle_b = Arc::new(tune_into(&staging_b, 81));
    let fp_a = bundle_a.fingerprint().unwrap().to_string();
    let fp_b = bundle_b.fingerprint().unwrap().to_string();
    assert_ne!(fp_a, fp_b);
    copy_checkpoints(&staging_a, &watch).unwrap();

    let addr = format!("127.0.0.1:{}", free_port());
    let mut cfg = fleet_config(&addr, 2, &watch, "roll");
    cfg.watch_dirs = vec![watch.clone()];
    let fleet = Fleet::start(cfg).unwrap();
    fleet.wait_ready(Duration::from_secs(60)).unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..3usize {
            let (stop, addr) = (stop.clone(), addr.clone());
            let (bundle_a, bundle_b) = (bundle_a.clone(), bundle_b.clone());
            let (fp_a, fp_b) = (fp_a.clone(), fp_b.clone());
            handles.push(scope.spawn(move || {
                let mut client =
                    ServedClient::connect_str_with_retry(&addr, Duration::from_secs(10))
                        .unwrap();
                let mut rng = Rng::new(5000 + t as u64);
                let (mut saw_a, mut saw_b) = (false, false);
                while !stop.load(Ordering::Relaxed) {
                    let q = vec![rng.uniform(64.0, 8192.0), rng.uniform(64.0, 8192.0)];
                    let d = decide_with_retry(&mut client, &addr, &q);
                    let fp = d.fingerprint.expect("checkpoint bundles carry fingerprints");
                    if fp == fp_a {
                        assert_eq!(d.values, bundle_a.decide(&q), "epoch-A mismatch {q:?}");
                        saw_a = true;
                    } else if fp == fp_b {
                        assert_eq!(d.values, bundle_b.decide(&q), "epoch-B mismatch {q:?}");
                        saw_b = true;
                    } else {
                        panic!("unknown fingerprint {fp}");
                    }
                }
                (saw_a, saw_b)
            }));
        }

        // Epoch A traffic first, then land epoch B in the watched dir —
        // the supervisor must roll the children one at a time.
        std::thread::sleep(Duration::from_millis(300));
        copy_checkpoints(&staging_b, &watch).unwrap();

        let rolled = fleet.wait_fingerprint(&fp_b, Duration::from_secs(120));
        if rolled {
            std::thread::sleep(Duration::from_millis(200));
        }
        stop.store(true, Ordering::Relaxed);
        assert!(rolled, "fleet never converged on the new fingerprint");

        let (mut saw_a_any, mut saw_b_any) = (false, false);
        for h in handles {
            let (a, b) = h.join().unwrap();
            saw_a_any |= a;
            saw_b_any |= b;
        }
        assert!(saw_a_any, "no traffic was served by the pre-redeploy epoch");
        assert!(saw_b_any, "no traffic was served by the post-redeploy epoch");
    });

    // Redeploys are drains, not crashes: no restart counted, nothing
    // degraded.
    let children = fleet.children();
    assert!(
        children.iter().all(|c| c.state == ChildState::Running),
        "{children:?}"
    );
    drop(fleet);
    for d in [&staging_a, &staging_b, &watch] {
        std::fs::remove_dir_all(d).ok();
    }
}

#[test]
fn injected_spawn_and_health_faults_degrade_and_recover_as_designed() {
    let _g = gate();
    let dir = tmp_dir("faults");
    let reference = tune_into(&dir, 72);

    // fleet.spawn=err: no child can ever be exec'd. Spawn failures are
    // deaths, so the circuit breaker parks the (only) slot and
    // wait_ready reports a fully-degraded fleet instead of hanging.
    {
        let fp = failpoint::arm_scoped("fleet.spawn=err").unwrap();
        let addr = format!("127.0.0.1:{}", free_port());
        let mut cfg = fleet_config(&addr, 1, &dir, "faults_spawn");
        cfg.crash_k = 2;
        cfg.crash_window = Duration::from_secs(60);
        let fleet = Fleet::start(cfg).unwrap();
        let err = fleet.wait_ready(Duration::from_secs(60)).unwrap_err();
        assert!(err.contains("degraded"), "unexpected readiness error: {err}");
        assert!(failpoint::hits("fleet.spawn") >= 2);
        drop(fleet);
        drop(fp);
    }

    // fleet.health=err: a healthy child whose probes all fail looks
    // hung; the supervisor kills and restarts it. Disarm, and the
    // replacement probes healthy again — full recovery.
    {
        let addr = format!("127.0.0.1:{}", free_port());
        let mut cfg = fleet_config(&addr, 1, &dir, "faults_health");
        cfg.hung_after = 2;
        cfg.crash_k = 50; // keep the breaker out of this test's way
        let fleet = Fleet::start(cfg).unwrap();
        fleet.wait_ready(Duration::from_secs(60)).unwrap();
        let pid = fleet.children()[0].pid.unwrap();

        let fp = failpoint::arm_scoped("fleet.health=err").unwrap();
        wait_for("hung child to be killed", Duration::from_secs(15), || {
            fleet.children()[0].pid.map_or(true, |p| p != pid)
        });
        drop(fp);

        wait_for("replacement to probe healthy", Duration::from_secs(30), || {
            let c = &fleet.children()[0];
            c.state == ChildState::Running && c.pid.is_some_and(|p| p != pid)
        });
        assert!(fleet.children()[0].restarts >= 1);

        // And it serves, correctly.
        let mut client =
            ServedClient::connect_str_with_retry(&addr, Duration::from_secs(10)).unwrap();
        let q = vec![1500.0, 2500.0];
        let d = decide_with_retry(&mut client, &addr, &q);
        assert_eq!(d.values, reference.decide(&q));
        drop(fleet);
    }
    std::fs::remove_dir_all(&dir).ok();
}
