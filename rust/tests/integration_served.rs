//! End-to-end daemon tests: `mlkaps served` must answer concurrent
//! clients **bit-identically** to in-process [`TreeBundle::decide`], and
//! survive an atomic hot-reload under live traffic with zero dropped or
//! erroneous requests — old and new run fingerprints both observed.
//!
//! The daemon is started in-process on an ephemeral port (port 0) and
//! driven over real TCP sockets by the Rust client; one test also speaks
//! the newline-text framing over a raw socket, covering both framings of
//! `docs/protocol.md`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use mlkaps::kernels::toy_sum::ToySum;
use mlkaps::optimizer::nsga2::Nsga2Params;
use mlkaps::pipeline::checkpoint::{copy_checkpoints, PipelineRun};
use mlkaps::pipeline::{MlkapsConfig, SamplerChoice};
use mlkaps::runtime::server::client::ServedClient;
use mlkaps::runtime::server::daemon::{Daemon, DaemonConfig};
use mlkaps::runtime::server::ServedRegistry;
use mlkaps::runtime::serving::TreeBundle;
use mlkaps::surrogate::gbdt::GbdtParams;
use mlkaps::util::json::Value;
use mlkaps::util::rng::Rng;

fn config(seed: u64) -> MlkapsConfig {
    MlkapsConfig {
        total_samples: 120,
        batch_size: 60,
        sampler: SamplerChoice::Lhs,
        gbdt: GbdtParams { n_trees: 20, ..Default::default() },
        ga: Nsga2Params { pop_size: 8, generations: 5, ..Default::default() },
        opt_grid: 4,
        tree_depth: 4,
        threads: 1,
        seed,
    }
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("mlkaps_served_it_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Tune toy-sum with `seed` into `dir`, returning the serving bundle.
fn tune_into(dir: &PathBuf, seed: u64) -> TreeBundle {
    PipelineRun::new(config(seed), dir.clone()).run(&ToySum::new(seed)).unwrap();
    TreeBundle::load_checkpoint_dir(dir).unwrap()
}

fn daemon_config() -> DaemonConfig {
    DaemonConfig {
        addr: "127.0.0.1:0".into(),
        batch_max: 64,
        // Wider than the production default (200µs) so concurrent test
        // clients reliably coalesce even on a single-core CI runner.
        batch_window: Duration::from_millis(1),
        poll_interval: Duration::from_millis(25),
        threads: 1,
        queue_capacity: 1024,
        ..Default::default()
    }
}

#[test]
fn concurrent_clients_get_bit_identical_decisions() {
    let dir = tmp_dir("concurrent");
    let reference = tune_into(&dir, 70);

    let mut reg = ServedRegistry::new(None);
    reg.register_dir(&dir, None).unwrap();
    let mut daemon = Daemon::start(reg, daemon_config()).unwrap();
    let addr = daemon.local_addr();

    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 100;
    let reference = Arc::new(reference);
    let mut max_batch_seen = 1usize;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..CLIENTS {
            let reference = reference.clone();
            handles.push(scope.spawn(move || {
                let mut client = ServedClient::connect(addr).unwrap();
                let mut rng = Rng::new(1000 + t as u64);
                let mut max_batch = 1usize;
                for _ in 0..PER_CLIENT {
                    let q = vec![rng.uniform(64.0, 8192.0), rng.uniform(64.0, 8192.0)];
                    let d = client.decide("toy-sum", &q, None).unwrap();
                    assert_eq!(
                        d.values,
                        reference.decide(&q),
                        "served decision diverged from in-process decide for {q:?}"
                    );
                    assert!(d.fingerprint.is_some());
                    assert!(d.batch >= 1);
                    max_batch = max_batch.max(d.batch);
                }
                max_batch
            }));
        }
        for h in handles {
            max_batch_seen = max_batch_seen.max(h.join().unwrap());
        }
    });

    // Telemetry saw every request; concurrent traffic produced at least
    // one multi-row micro-batch (4 clients × the widened 1ms test
    // window configured in `daemon_config`).
    let mut client = ServedClient::connect(addr).unwrap();
    client.ping().unwrap();
    assert_eq!(client.list_names().unwrap(), vec!["toy-sum".to_string()]);
    let stats = client.stats().unwrap();
    let k = stats.get("kernels").and_then(|k| k.get("toy-sum")).unwrap();
    let requests = k.get("requests").and_then(Value::as_usize).unwrap();
    assert!(requests >= CLIENTS * PER_CLIENT, "requests={requests}");
    assert_eq!(k.get("errors").and_then(Value::as_usize), Some(0));
    assert!(
        max_batch_seen >= 2,
        "4 concurrent clients never coalesced into one micro-batch"
    );

    // Dimension mismatches are clean errors, not daemon crashes.
    let err = client.decide("toy-sum", &[1.0, 2.0, 3.0], None).unwrap_err();
    assert!(err.contains("takes 2"), "{err}");
    let err = client.decide("nope", &[1.0, 2.0], None).unwrap_err();
    assert!(err.contains("toy-sum"), "{err}");

    client.shutdown().unwrap();
    daemon.wait();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn text_framing_serves_the_same_decisions() {
    let dir = tmp_dir("text");
    let reference = tune_into(&dir, 71);

    let mut reg = ServedRegistry::new(None);
    reg.register_dir(&dir, None).unwrap();
    let daemon = Daemon::start(reg, daemon_config()).unwrap();

    let stream = TcpStream::connect(daemon.local_addr()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut line = String::new();
    let mut roundtrip = |req: &str, line: &mut String| {
        writer.write_all(req.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        line.clear();
        reader.read_line(line).unwrap();
        mlkaps::util::json::parse(line.trim()).unwrap()
    };

    let v = roundtrip("PING", &mut line);
    assert_eq!(v.get("pong").and_then(Value::as_bool), Some(true));

    let q = vec![1234.0, 5678.0];
    let v = roundtrip("{\"kernel\":\"toy-sum\",\"input\":[1234,5678],\"id\":\"r1\"}", &mut line);
    assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true), "{line}");
    assert_eq!(v.get("id").and_then(Value::as_str), Some("r1"));
    let served: Vec<f64> = v
        .get("values")
        .and_then(Value::as_arr)
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap())
        .collect();
    assert_eq!(served, reference.decide(&q), "text-mode decision diverged");

    let v = roundtrip("STATS", &mut line);
    assert!(v.get("kernels").and_then(|k| k.get("toy-sum")).is_some());
    let v = roundtrip("gibberish", &mut line);
    assert_eq!(v.get("ok").and_then(Value::as_bool), Some(false));

    let v = roundtrip("SHUTDOWN", &mut line);
    assert_eq!(v.get("shutdown").and_then(Value::as_bool), Some(true));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hot_reload_under_load_drops_nothing_and_serves_both_epochs() {
    let staging_a = tmp_dir("reload_a");
    let staging_b = tmp_dir("reload_b");
    let watch = tmp_dir("reload_watch");

    // Two complete runs with different seeds → different fingerprints.
    let bundle_a = tune_into(&staging_a, 80);
    let bundle_b = tune_into(&staging_b, 81);
    let fp_a = bundle_a.fingerprint().unwrap().to_string();
    let fp_b = bundle_b.fingerprint().unwrap().to_string();
    assert_ne!(fp_a, fp_b);

    // The daemon watches `watch`, which starts as run A.
    copy_checkpoints(&staging_a, &watch).unwrap();
    let mut reg = ServedRegistry::new(None);
    reg.register_dir(&watch, None).unwrap();
    let mut daemon = Daemon::start(reg, daemon_config()).unwrap();
    let addr = daemon.local_addr();

    let stop = Arc::new(AtomicBool::new(false));
    let bundle_a = Arc::new(bundle_a);
    let bundle_b = Arc::new(bundle_b);

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..3usize {
            let stop = stop.clone();
            let (bundle_a, bundle_b) = (bundle_a.clone(), bundle_b.clone());
            let (fp_a, fp_b) = (fp_a.clone(), fp_b.clone());
            handles.push(scope.spawn(move || {
                let mut client = ServedClient::connect(addr).unwrap();
                let mut rng = Rng::new(2000 + t as u64);
                let (mut saw_a, mut saw_b, mut n) = (false, false, 0u64);
                while !stop.load(Ordering::Relaxed) {
                    let q = vec![rng.uniform(64.0, 8192.0), rng.uniform(64.0, 8192.0)];
                    // Zero tolerated errors: every request during the
                    // swap must be answered, by one epoch or the other.
                    let d = client.decide("toy-sum", &q, None).unwrap();
                    let fp = d.fingerprint.expect("checkpoint bundles carry fingerprints");
                    if fp == fp_a {
                        assert_eq!(d.values, bundle_a.decide(&q), "epoch-A mismatch {q:?}");
                        saw_a = true;
                    } else if fp == fp_b {
                        assert_eq!(d.values, bundle_b.decide(&q), "epoch-B mismatch {q:?}");
                        saw_b = true;
                    } else {
                        panic!("unknown fingerprint {fp}");
                    }
                    n += 1;
                }
                (saw_a, saw_b, n)
            }));
        }

        // Let traffic run on epoch A, then land the re-tuned run B in
        // the watched directory mid-traffic.
        std::thread::sleep(Duration::from_millis(150));
        copy_checkpoints(&staging_b, &watch).unwrap();

        // Wait until the poller (25ms cadence) has swapped to B. Always
        // stop traffic before asserting, so a failure can't leave the
        // scoped client threads spinning forever.
        let mut control = ServedClient::connect(addr).unwrap();
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut reloaded = false;
        while Instant::now() < deadline {
            let stats = control.stats().unwrap();
            let k = stats.get("kernels").and_then(|k| k.get("toy-sum")).unwrap();
            if k.get("fingerprint").and_then(Value::as_str) == Some(fp_b.as_str()) {
                reloaded = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        if reloaded {
            // Keep serving from the new epoch a little before stopping.
            std::thread::sleep(Duration::from_millis(100));
        }
        stop.store(true, Ordering::Relaxed);
        assert!(reloaded, "hot reload never happened");

        let (mut saw_a_any, mut saw_b_any, mut total) = (false, false, 0u64);
        for h in handles {
            let (a, b, n) = h.join().unwrap();
            saw_a_any |= a;
            saw_b_any |= b;
            total += n;
        }
        assert!(saw_a_any, "no traffic was served by the pre-reload epoch");
        assert!(saw_b_any, "no traffic was served by the post-reload epoch");
        assert!(total > 0);

        let stats = control.stats().unwrap();
        let k = stats.get("kernels").and_then(|k| k.get("toy-sum")).unwrap();
        assert_eq!(
            k.get("errors").and_then(Value::as_usize),
            Some(0),
            "requests were dropped or errored during the hot reload"
        );
        assert!(k.get("reloads").and_then(Value::as_usize).unwrap() >= 1);
        control.shutdown().unwrap();
    });

    daemon.wait();
    for d in [&staging_a, &staging_b, &watch] {
        std::fs::remove_dir_all(d).ok();
    }
}

#[test]
fn drain_verb_answers_in_flight_then_exits_cleanly() {
    let dir = tmp_dir("drain");
    let reference = tune_into(&dir, 72);

    let mut reg = ServedRegistry::new(None);
    reg.register_dir(&dir, None).unwrap();
    let mut daemon = Daemon::start(reg, daemon_config()).unwrap();
    let addr = daemon.local_addr();

    // A second connection with a request in flight while DRAIN lands on
    // the first: the decide must still be answered normally.
    let mut worker = ServedClient::connect(addr).unwrap();
    let q = vec![1500.0, 2500.0];
    let d = worker.decide("toy-sum", &q, None).unwrap();
    assert_eq!(d.values, reference.decide(&q));

    let mut control = ServedClient::connect(addr).unwrap();
    control.drain().unwrap();

    // The daemon's threads must all exit on their own (DRAIN, not drop).
    daemon.wait();

    // Post-drain, the endpoint is gone: connects fail outright or are
    // closed without service.
    let refused = match TcpStream::connect_timeout(&addr, Duration::from_secs(2)) {
        Err(_) => true,
        Ok(mut s) => {
            s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
            s.write_all(b"PING\n").ok();
            let mut buf = String::new();
            // EOF (0 bytes) or an error both mean "no longer serving".
            matches!(BufReader::new(&mut s).read_line(&mut buf), Ok(0) | Err(_))
        }
    };
    assert!(refused, "daemon still serving after DRAIN");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stalled_connections_are_disconnected_by_the_read_timeout() {
    let dir = tmp_dir("timeout");
    tune_into(&dir, 73);

    let mut reg = ServedRegistry::new(None);
    reg.register_dir(&dir, None).unwrap();
    let cfg = DaemonConfig {
        read_timeout: Duration::from_millis(100),
        ..daemon_config()
    };
    let mut daemon = Daemon::start(reg, cfg).unwrap();
    let addr = daemon.local_addr();

    // Open a connection, send half a request line, then stall: the
    // daemon must hang up instead of holding the thread forever.
    let mut stalled = TcpStream::connect(addr).unwrap();
    stalled.write_all(b"{\"kernel\":\"toy-sum\"").unwrap();
    stalled.flush().unwrap();
    stalled.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = Vec::new();
    let hung_up = matches!(std::io::Read::read_to_end(&mut stalled, &mut buf), Ok(_));
    assert!(hung_up, "expected EOF from the daemon's read timeout");

    // The daemon is unaffected: a well-behaved client still gets served.
    let mut client = ServedClient::connect(addr).unwrap();
    client.ping().unwrap();
    client.shutdown().unwrap();
    daemon.wait();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn quantized_memo_mode_survives_registration_and_reports_in_stats() {
    let dir = tmp_dir("memo_quant");
    let reference = tune_into(&dir, 74);

    let mut reg = ServedRegistry::new(None);
    reg.set_memo_mode(mlkaps::runtime::serving::MemoMode::Quantized);
    reg.register_dir(&dir, None).unwrap();
    let mut daemon = Daemon::start(reg, daemon_config()).unwrap();

    let mut client = ServedClient::connect(daemon.local_addr()).unwrap();
    // Sequential singleton requests take the memoized scalar path; the
    // second, bit-identical input must hit.
    let q = vec![3000.0, 4000.0];
    let a = client.decide("toy-sum", &q, None).unwrap();
    let b = client.decide("toy-sum", &q, None).unwrap();
    assert_eq!(a.values, b.values);
    assert_eq!(a.values, reference.decide(&q));

    let stats = client.stats().unwrap();
    let k = stats.get("kernels").and_then(|k| k.get("toy-sum")).unwrap();
    assert_eq!(k.get("cache_mode").and_then(Value::as_str), Some("quantized"));
    let hits = k.get("cache_hits").and_then(Value::as_usize).unwrap();
    let exact = k.get("cache_hits_exact").and_then(Value::as_usize).unwrap();
    let quant = k.get("cache_hits_quantized").and_then(Value::as_usize).unwrap();
    assert!(hits >= 1, "repeat input must hit the memo cache");
    assert_eq!(exact + quant, hits, "split telemetry must sum to hits");

    client.shutdown().unwrap();
    daemon.wait();
    std::fs::remove_dir_all(&dir).ok();
}

/// The tentpole, end to end: serve → observe (reservoir) → pull
/// (`SAMPLES`, both framings) → re-tune (bit-reproducibly) → redeploy
/// (hot-reload) → prewarm (first post-swap request is a cache hit).
/// Zero requests dropped or errored across the whole loop.
#[test]
fn closed_loop_observe_retune_and_prewarmed_hot_reload() {
    let staging = tmp_dir("loop_staging");
    let watch = tmp_dir("loop_watch");
    tune_into(&staging, 75);
    copy_checkpoints(&staging, &watch).unwrap();

    let mut reg = ServedRegistry::new(None);
    // A small reservoir so the test exercises replacement (seen > cap).
    reg.set_reservoir_cap(64);
    reg.register_dir(&watch, None).unwrap();
    let mut daemon = Daemon::start(reg, daemon_config()).unwrap();
    let addr = daemon.local_addr();

    // Phase 1: concurrent production-shaped traffic fills the reservoir.
    const CLIENTS: usize = 3;
    const PER_CLIENT: usize = 60;
    std::thread::scope(|scope| {
        for t in 0..CLIENTS {
            scope.spawn(move || {
                let mut client = ServedClient::connect(addr).unwrap();
                let mut rng = Rng::new(3000 + t as u64);
                for _ in 0..PER_CLIENT {
                    let q = vec![rng.uniform(64.0, 8192.0), rng.uniform(64.0, 8192.0)];
                    client.decide("toy-sum", &q, None).unwrap();
                }
            });
        }
    });

    let mut client = ServedClient::connect(addr).unwrap();

    // STATS reports reservoir occupancy plus the windowed telemetry.
    let stats = client.stats().unwrap();
    let k = stats.get("kernels").and_then(|k| k.get("toy-sum")).unwrap();
    assert_eq!(
        k.get("samples_seen").and_then(Value::as_usize),
        Some(CLIENTS * PER_CLIENT)
    );
    assert_eq!(k.get("samples_held").and_then(Value::as_usize), Some(64));
    assert_eq!(k.get("samples_cap").and_then(Value::as_usize), Some(64));
    assert_eq!(
        k.get("window_requests").and_then(Value::as_usize),
        Some(CLIENTS * PER_CLIENT)
    );
    assert!(k.get("window_requests_per_sec").and_then(Value::as_f64).unwrap() > 0.0);
    assert!(k.get("window_mean_batch").and_then(Value::as_f64).unwrap() >= 1.0);
    // The window resets on read; the cumulative counters don't.
    let stats = client.stats().unwrap();
    let k = stats.get("kernels").and_then(|k| k.get("toy-sum")).unwrap();
    assert_eq!(k.get("window_requests").and_then(Value::as_usize), Some(0));
    assert_eq!(
        k.get("requests").and_then(Value::as_usize),
        Some(CLIENTS * PER_CLIENT)
    );

    // SAMPLES over the binary framing: the whole reservoir, then a
    // limited prefix — reads never perturb the reservoir.
    let rows = client.sample_rows("toy-sum", None).unwrap();
    assert_eq!(rows.len(), 64);
    assert!(rows.iter().all(|r| r.len() == 2));
    let few = client.sample_rows("toy-sum", Some(5)).unwrap();
    assert_eq!(few, rows[..5].to_vec());
    let err = client.samples(Some("nope"), None).unwrap_err();
    assert!(err.contains("nope"), "{err}");

    // SAMPLES over the raw text framing: same reservoir, same rows.
    {
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        writer.write_all(b"SAMPLES\n").unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = mlkaps::util::json::parse(line.trim()).unwrap();
        assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true), "{line}");
        let entry = v.get("samples").and_then(|s| s.get("toy-sum")).unwrap();
        assert_eq!(
            entry.get("seen").and_then(Value::as_usize),
            Some(CLIENTS * PER_CLIENT)
        );
        let text_rows = entry.get("rows").and_then(Value::as_arr).unwrap();
        assert_eq!(text_rows.len(), 64);
        let first: Vec<f64> =
            text_rows[0].as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect();
        assert_eq!(first, rows[0]);
    }

    // Phase 2: re-tune from the pulled reservoir — bit-reproducible
    // (two retunes from the same rows produce byte-identical chains).
    let r1 = tmp_dir("loop_retune1");
    let r2 = tmp_dir("loop_retune2");
    copy_checkpoints(&watch, &r1).unwrap();
    copy_checkpoints(&watch, &r2).unwrap();
    let out1 = PipelineRun::new(config(75), r1.clone()).retune(&rows).unwrap();
    let out2 = PipelineRun::new(config(75), r2.clone()).retune(&rows).unwrap();
    assert_eq!(out1.fingerprint, out2.fingerprint, "retune is not reproducible");
    assert_ne!(out1.fingerprint, out1.base_fingerprint, "retune must flip the run id");
    assert!(out1.boosted >= 1, "served rows boosted no grid point");
    for f in [
        "checkpoint.json",
        "stage1_dataset.json",
        "stage2_surrogate.json",
        "stage3_grid.json",
        "stage4_trees.json",
    ] {
        assert_eq!(
            std::fs::read(r1.join(f)).unwrap(),
            std::fs::read(r2.join(f)).unwrap(),
            "{f} differs between identical retunes"
        );
    }
    // The rewritten chain still verifies and loads.
    let retuned = TreeBundle::load_checkpoint_dir(&r1).unwrap();
    assert_eq!(retuned.fingerprint(), Some(out1.fingerprint.as_str()));

    // Phase 3: land the retuned chain in the watched directory and wait
    // for the daemon to swap (nudging with the RELOAD verb).
    copy_checkpoints(&r1, &watch).unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let _ = client.reload();
        let stats = client.stats().unwrap();
        let k = stats.get("kernels").and_then(|k| k.get("toy-sum")).unwrap();
        if k.get("fingerprint").and_then(Value::as_str) == Some(out1.fingerprint.as_str())
        {
            break;
        }
        assert!(Instant::now() < deadline, "daemon never reloaded the retuned run");
        std::thread::sleep(Duration::from_millis(10));
    }

    // The swap prewarmed the new epoch's memo cache from the reservoir:
    // each of the 64 held rows was replayed as a miss, and the first
    // real request after the swap — the last-prewarmed row, which
    // nothing can have evicted — is answered from the cache.
    let stats = client.stats().unwrap();
    let k = stats.get("kernels").and_then(|k| k.get("toy-sum")).unwrap();
    let hits0 = k.get("cache_hits").and_then(Value::as_usize).unwrap();
    let misses0 = k.get("cache_misses").and_then(Value::as_usize).unwrap();
    assert_eq!(misses0, 64, "prewarm must replay every reservoir row (as misses)");

    let warm = rows.last().unwrap();
    let d = client.decide("toy-sum", warm, None).unwrap();
    assert_eq!(d.fingerprint.as_deref(), Some(out1.fingerprint.as_str()));
    assert_eq!(d.values, retuned.decide(warm), "post-swap decision diverged");

    let stats = client.stats().unwrap();
    let k = stats.get("kernels").and_then(|k| k.get("toy-sum")).unwrap();
    assert_eq!(
        k.get("cache_hits").and_then(Value::as_usize),
        Some(hits0 + 1),
        "first post-swap request was not a prewarmed cache hit"
    );
    assert_eq!(k.get("cache_misses").and_then(Value::as_usize), Some(misses0));

    // Zero dropped or errored decisions across the whole loop.
    assert_eq!(k.get("errors").and_then(Value::as_usize), Some(0));
    assert!(k.get("reloads").and_then(Value::as_usize).unwrap() >= 1);

    client.shutdown().unwrap();
    daemon.wait();
    for d in [&staging, &watch, &r1, &r2] {
        std::fs::remove_dir_all(d).ok();
    }
}

/// Satellite-2 regression: `--memo quantized` must not serve from a
/// cache keyed by the *previous* epoch's split thresholds after a
/// hot-reload. The quantizer is rebuilt (not merely cleared) atomically
/// with the swap; a stale quantizer would alias inputs that share an
/// old-epoch cell but straddle a new-epoch threshold into one cache
/// entry, returning one input's config for the other.
#[test]
fn quantized_cache_rekeys_on_hot_reload_with_changed_thresholds() {
    let staging_a = tmp_dir("rekey_a");
    let staging_b = tmp_dir("rekey_b");
    let watch = tmp_dir("rekey_watch");
    tune_into(&staging_a, 76);
    // A different seed tunes different trees → different thresholds.
    let bundle_b = tune_into(&staging_b, 77);
    let fp_b = bundle_b.fingerprint().unwrap().to_string();
    copy_checkpoints(&staging_a, &watch).unwrap();

    let mut reg = ServedRegistry::new(None);
    reg.set_memo_mode(mlkaps::runtime::serving::MemoMode::Quantized);
    reg.register_dir(&watch, None).unwrap();
    let mut daemon = Daemon::start(reg, daemon_config()).unwrap();
    let addr = daemon.local_addr();
    let mut client = ServedClient::connect(addr).unwrap();

    // Populate epoch A's quantized cache with a probe sweep.
    let mut rng = Rng::new(4000);
    let probes: Vec<Vec<f64>> = (0..50)
        .map(|_| vec![rng.uniform(64.0, 8192.0), rng.uniform(64.0, 8192.0)])
        .collect();
    for q in &probes {
        client.decide("toy-sum", q, None).unwrap();
    }

    // Swap epochs under the same watch directory.
    copy_checkpoints(&staging_b, &watch).unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let _ = client.reload();
        let stats = client.stats().unwrap();
        let k = stats.get("kernels").and_then(|k| k.get("toy-sum")).unwrap();
        if k.get("fingerprint").and_then(Value::as_str) == Some(fp_b.as_str()) {
            break;
        }
        assert!(Instant::now() < deadline, "daemon never swapped to epoch B");
        std::thread::sleep(Duration::from_millis(10));
    }

    // Every probe, decided twice so the second answer comes from the
    // rebuilt cache, must match epoch B's trees bit-exactly. Under the
    // stale-quantizer bug some of these return a *different* probe's
    // config (cross-threshold aliasing) — this sweep is the regression.
    for q in &probes {
        let d1 = client.decide("toy-sum", q, None).unwrap();
        let d2 = client.decide("toy-sum", q, None).unwrap();
        assert_eq!(d1.values, bundle_b.decide(q), "post-swap quantized alias for {q:?}");
        assert_eq!(d2.values, d1.values);
        assert_eq!(d1.fingerprint.as_deref(), Some(fp_b.as_str()));
    }
    let stats = client.stats().unwrap();
    let k = stats.get("kernels").and_then(|k| k.get("toy-sum")).unwrap();
    assert_eq!(k.get("cache_mode").and_then(Value::as_str), Some("quantized"));
    let hits = k.get("cache_hits").and_then(Value::as_usize).unwrap();
    let exact = k.get("cache_hits_exact").and_then(Value::as_usize).unwrap();
    let quant = k.get("cache_hits_quantized").and_then(Value::as_usize).unwrap();
    assert_eq!(exact + quant, hits);
    assert_eq!(k.get("errors").and_then(Value::as_usize), Some(0));

    client.shutdown().unwrap();
    daemon.wait();
    for d in [&staging_a, &staging_b, &watch] {
        std::fs::remove_dir_all(d).ok();
    }
}

#[test]
fn profile_variants_route_and_reload_verb_works() {
    let dir_spr = tmp_dir("prof_spr");
    let dir_knm = tmp_dir("prof_knm");
    let spr = tune_into(&dir_spr, 90);
    let knm = tune_into(&dir_knm, 91);

    let mut reg = ServedRegistry::new(Some("spr".into()));
    reg.register_dir(&dir_spr, Some("toy@spr")).unwrap();
    reg.register_dir(&dir_knm, Some("toy@knm")).unwrap();
    let mut daemon = Daemon::start(reg, daemon_config()).unwrap();

    let mut client = ServedClient::connect(daemon.local_addr()).unwrap();
    assert_eq!(
        client.list_names().unwrap(),
        vec!["toy@knm".to_string(), "toy@spr".to_string()]
    );
    let q = vec![2000.0, 3000.0];
    // Explicit per-request profile, then the daemon default (spr).
    let d = client.decide("toy", &q, Some("knm")).unwrap();
    assert_eq!(d.variant, "toy@knm");
    assert_eq!(d.values, knm.decide(&q));
    let d = client.decide("toy", &q, None).unwrap();
    assert_eq!(d.variant, "toy@spr");
    assert_eq!(d.values, spr.decide(&q));

    // RELOAD with unchanged fingerprints swaps nothing.
    assert!(client.reload().unwrap().is_empty());

    client.shutdown().unwrap();
    daemon.wait();
    std::fs::remove_dir_all(&dir_spr).ok();
    std::fs::remove_dir_all(&dir_knm).ok();
}

#[test]
#[cfg(unix)]
fn unix_socket_daemon_serves_bit_identical_decisions() {
    let dir = tmp_dir("unix");
    let reference = tune_into(&dir, 76);
    let sock =
        std::env::temp_dir().join(format!("mlkaps_served_it_{}.sock", std::process::id()));
    std::fs::remove_file(&sock).ok();

    let mut reg = ServedRegistry::new(None);
    reg.register_dir(&dir, None).unwrap();
    let cfg = DaemonConfig {
        addr: format!("unix:{}", sock.display()),
        ..daemon_config()
    };
    let mut daemon = Daemon::start(reg, cfg).unwrap();
    let addr = daemon.local_display();
    assert_eq!(addr, format!("unix:{}", sock.display()));
    assert!(sock.exists(), "daemon should have bound the unix socket");

    // Binary framing over the unix transport: decisions bit-identical
    // to the in-process bundle, same as the TCP tests.
    let mut client = ServedClient::connect_str(&addr).unwrap();
    client.ping().unwrap();
    let mut rng = Rng::new(7600);
    for _ in 0..50 {
        let q = vec![rng.uniform(64.0, 8192.0), rng.uniform(64.0, 8192.0)];
        let d = client.decide("toy-sum", &q, None).unwrap();
        assert_eq!(
            d.values,
            reference.decide(&q),
            "unix-socket decision diverged from in-process decide for {q:?}"
        );
    }

    // Newline-text framing is auto-detected on the same listener.
    {
        use std::os::unix::net::UnixStream;
        let mut raw = UnixStream::connect(&sock).unwrap();
        raw.write_all(b"PING\n").unwrap();
        let mut line = String::new();
        BufReader::new(raw.try_clone().unwrap()).read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\": true") || line.contains("\"ok\":true"), "{line}");
    }

    client.shutdown().unwrap();
    daemon.wait();
    assert!(!sock.exists(), "daemon should unlink its socket on shutdown");
    std::fs::remove_dir_all(&dir).ok();
}
