//! Property suite: `CompiledForest` batch prediction must be
//! **bit-identical** to the scalar tree-arena `predict` — across random
//! forests covering categorical one-vs-rest splits, NaN default-left
//! routing, unseen categories, 1-node constant trees, tiny bin tables and
//! L1/L2 losses, at several thread counts, and after a JSON round-trip.
//!
//! Exactness (assert_eq on f64 bits, no epsilon) is what lets the grid
//! optimizer, GA-Adaptive and the checkpoint resume path switch to
//! `predict_batch` without perturbing any seeded result.

use mlkaps::data::Dataset;
use mlkaps::surrogate::gbdt::{Gbdt, GbdtParams, Loss};
use mlkaps::surrogate::Surrogate;
use mlkaps::util::rng::Rng;

/// Distinct categories per categorical feature.
const N_CATS: usize = 6;

/// Build a random fitting problem: mixed numeric/categorical features,
/// a lumpy objective, and random GBDT hyperparameters.
fn random_case(rng: &mut Rng) -> (Gbdt, Dataset) {
    let d = 1 + rng.below(5);
    let n = 30 + rng.below(370);
    let categorical: Vec<bool> = (0..d).map(|_| rng.bool(0.3)).collect();
    let mut data = Dataset::with_capacity(n);
    for _ in 0..n {
        let x: Vec<f64> = categorical
            .iter()
            .map(|&c| {
                if c {
                    rng.below(N_CATS) as f64
                } else {
                    rng.uniform(-3.0, 3.0)
                }
            })
            .collect();
        let y = x
            .iter()
            .enumerate()
            .map(|(j, &v)| if j % 2 == 0 { (v * 1.3).sin() } else { v * v * 0.2 })
            .sum::<f64>()
            + rng.uniform(-0.1, 0.1);
        data.push(x, y);
    }
    let params = GbdtParams {
        n_trees: 1 + rng.below(50),
        max_leaves: 2 + rng.below(30),
        min_samples_leaf: 1 + rng.below(8),
        bagging_fraction: rng.uniform(0.5, 1.0),
        feature_fraction: rng.uniform(0.5, 1.0),
        // Occasionally degenerate bin budgets (regression: used to make
        // every feature unsplittable).
        max_bins: if rng.bool(0.2) { rng.below(3) } else { 32 + rng.below(200) },
        loss: if rng.bool(0.5) { Loss::L1 } else { Loss::L2 },
        seed: rng.next_u64(),
        ..Default::default()
    };
    let mut m = Gbdt::with_mask(params, categorical);
    m.fit(&data);
    (m, data)
}

/// Random query block: training rows, fresh in-range points, out-of-range
/// numerics, unseen categories, and NaN injections.
fn random_queries(rng: &mut Rng, model: &Gbdt, data: &Dataset, n_q: usize) -> Vec<Vec<f64>> {
    let d = data.dim();
    (0..n_q)
        .map(|_| {
            let mut q: Vec<f64> = if rng.bool(0.3) {
                data.x[rng.below(data.len())].clone()
            } else {
                (0..d)
                    .map(|j| {
                        if model.categorical[j] {
                            // Sometimes a category never seen in training.
                            if rng.bool(0.2) {
                                (N_CATS + 2 + rng.below(4)) as f64
                            } else {
                                rng.below(N_CATS) as f64
                            }
                        } else {
                            rng.uniform(-6.0, 6.0) // beyond the training hull
                        }
                    })
                    .collect()
            };
            if rng.bool(0.25) {
                let j = rng.below(d);
                q[j] = f64::NAN;
            }
            q
        })
        .collect()
}

#[test]
fn prop_batch_is_bit_identical_to_scalar_predict() {
    let mut rng = Rng::new(0xF0_4E57);
    for trial in 0..30 {
        let (model, data) = random_case(&mut rng);
        assert!(
            model.compiled().is_some(),
            "trial {trial}: forest must compile after fit"
        );
        let queries = random_queries(&mut rng, &model, &data, 200);
        let scalar: Vec<f64> = queries.iter().map(|q| model.predict(q)).collect();
        for threads in [1usize, 2, 5, 0] {
            let batch = model.predict_batch_threads(&queries, threads);
            for (i, (s, b)) in scalar.iter().zip(&batch).enumerate() {
                assert!(
                    s.to_bits() == b.to_bits(),
                    "trial {trial} threads {threads} query {i} ({:?}): \
                     scalar {s} != batch {b}",
                    queries[i]
                );
            }
        }
    }
}

#[test]
fn prop_deserialized_forest_matches_original_batch() {
    let mut rng = Rng::new(0xDE_5E71);
    for trial in 0..10 {
        let (model, data) = random_case(&mut rng);
        let queries = random_queries(&mut rng, &model, &data, 120);
        let doc = model.to_json().to_string();
        let back = Gbdt::from_json(&mlkaps::util::json::parse(&doc).unwrap()).unwrap();
        assert!(back.compiled().is_some(), "trial {trial}: compile after from_json");
        let a = model.predict_batch(&queries);
        let b = back.predict_batch(&queries);
        let s: Vec<f64> = queries.iter().map(|q| back.predict(q)).collect();
        assert_eq!(a, b, "trial {trial}: batch changed across JSON round-trip");
        assert_eq!(b, s, "trial {trial}: deserialized batch != deserialized scalar");
    }
}

#[test]
fn prop_prebinned_codes_match_raw_batch() {
    // Caller-side quantization (the fused grid optimizer's bin-plan
    // path: constant columns coded once, the rest per row) must be
    // bit-identical to handing predict_batch the raw rows — including
    // NaN injections, out-of-domain numerics and unseen categories.
    let mut rng = Rng::new(0x9B1_4_B14);
    let mut prebinned_trials = 0;
    for trial in 0..30 {
        let (model, data) = random_case(&mut rng);
        let cf = model.compiled().expect("forest compiles after fit");
        let Some(plan) = cf.bin_plan() else { continue };
        prebinned_trials += 1;
        let queries = random_queries(&mut rng, &model, &data, 150);
        let d = cf.n_features();
        // Code a "constant prefix" of random width once per row via
        // code_prefix and the remainder via per-feature code(), exactly
        // how the lockstep optimizer splits input/design columns.
        let split = rng.below(d + 1);
        let mut codes = vec![0u16; queries.len() * d];
        for (r, q) in queries.iter().enumerate() {
            let row = &mut codes[r * d..(r + 1) * d];
            plan.code_prefix(&q[..split], &mut row[..split]);
            for j in split..d {
                row[j] = plan.code(j, q[j]);
            }
        }
        let raw = model.predict_batch_threads(&queries, 1);
        for threads in [1usize, 4, 0] {
            let pre = cf.predict_batch_prebinned(&codes, threads);
            assert_eq!(raw.len(), pre.len());
            for (i, (a, b)) in raw.iter().zip(&pre).enumerate() {
                assert!(
                    a.to_bits() == b.to_bits(),
                    "trial {trial} threads {threads} query {i} ({:?}): \
                     raw {a} != prebinned {b}",
                    queries[i]
                );
            }
        }
    }
    assert!(
        prebinned_trials >= 20,
        "only {prebinned_trials}/30 trials exercised the bin plan"
    );
}

#[test]
fn prop_oblivious_lockstep_equals_blocked_and_scalar() {
    // The branch-free oblivious lockstep walk, the branchy blocked walk,
    // and the scalar predict must agree bit for bit on every random
    // forest and query mix — NaN injections, out-of-domain numerics,
    // unseen categories — at several thread counts. The overlay is armed
    // and disarmed explicitly (environment-independent) so both layouts
    // run on the very same compiled forest.
    let mut rng = Rng::new(0x0B_11_510);
    let mut lockstep_trials = 0;
    for trial in 0..30 {
        let (model, data) = random_case(&mut rng);
        let cf = model.compiled().expect("forest compiles after fit");
        let Some(plan) = cf.bin_plan() else { continue };
        let mut lockstep = cf.clone();
        lockstep.set_traversal(mlkaps::surrogate::forest::Traversal::Lockstep);
        assert!(lockstep.is_lockstep(), "trial {trial}: prebinned forest must arm");
        let mut blocked = cf.clone();
        blocked.set_traversal(mlkaps::surrogate::forest::Traversal::Blocked);
        assert!(!blocked.is_lockstep());
        lockstep_trials += 1;

        // 100 queries: several LANES groups plus a ragged tail.
        let queries = random_queries(&mut rng, &model, &data, 100);
        let d = cf.n_features();
        let mut codes = vec![0u16; queries.len() * d];
        for (r, q) in queries.iter().enumerate() {
            plan.code_prefix(q, &mut codes[r * d..(r + 1) * d]);
        }
        let scalar: Vec<f64> = queries.iter().map(|q| model.predict(q)).collect();
        for threads in [1usize, 2, 8] {
            let lock = lockstep.predict_batch_prebinned(&codes, threads);
            let block = blocked.predict_batch_prebinned(&codes, threads);
            let oracle = lockstep.predict_batch_prebinned_blocked(&codes, threads);
            for i in 0..queries.len() {
                assert!(
                    scalar[i].to_bits() == lock[i].to_bits(),
                    "trial {trial} threads {threads} query {i} ({:?}): \
                     scalar {} != lockstep {}",
                    queries[i],
                    scalar[i],
                    lock[i]
                );
                assert_eq!(
                    scalar[i].to_bits(),
                    block[i].to_bits(),
                    "trial {trial} threads {threads} query {i}: blocked diverged"
                );
                assert_eq!(
                    scalar[i].to_bits(),
                    oracle[i].to_bits(),
                    "trial {trial} threads {threads} query {i}: forced-blocked \
                     oracle diverged on the lockstep forest"
                );
            }
        }
        // Raw-row batches on the armed forest route through the same
        // lockstep walk; they must stay pinned to scalar too.
        let raw = lockstep.predict_batch(&queries, 2);
        for (i, (s, b)) in scalar.iter().zip(&raw).enumerate() {
            assert_eq!(s.to_bits(), b.to_bits(), "trial {trial} raw query {i}");
        }
    }
    assert!(
        lockstep_trials >= 20,
        "only {lockstep_trials}/30 trials exercised the lockstep overlay"
    );
}

#[test]
fn one_node_constant_trees_are_exact() {
    // Constant target -> every tree is a single constant-fit leaf; the
    // compiled forest must reproduce the exact telescoped sum.
    let mut data = Dataset::new();
    for i in 0..80 {
        data.push(vec![i as f64, (i % 7) as f64], 42.5);
    }
    let mut m = Gbdt::with_mask(
        GbdtParams { n_trees: 25, ..Default::default() },
        vec![false, true],
    );
    m.fit(&data);
    let queries: Vec<Vec<f64>> =
        vec![vec![3.0, 2.0], vec![-100.0, 99.0], vec![f64::NAN, f64::NAN]];
    for threads in [1usize, 3] {
        let batch = m.predict_batch_threads(&queries, threads);
        for (q, &b) in queries.iter().zip(&batch) {
            assert_eq!(m.predict(q).to_bits(), b.to_bits(), "{q:?}");
        }
        assert!((batch[0] - 42.5).abs() < 1e-9);
    }
}

#[test]
fn large_batch_parallel_path_is_exact() {
    // Force the parallel row-block path (>= several blocks per worker)
    // and compare against scalar bit for bit.
    let mut rng = Rng::new(0xB16_B10C);
    let (model, data) = random_case(&mut rng);
    let queries = random_queries(&mut rng, &model, &data, 6000);
    let scalar: Vec<f64> = queries.iter().map(|q| model.predict(q)).collect();
    let batch = model.predict_batch(&queries); // adaptive -> parallel
    assert_eq!(scalar.len(), batch.len());
    for (s, b) in scalar.iter().zip(&batch) {
        assert_eq!(s.to_bits(), b.to_bits());
    }
}
