//! Integration tests: the full MLKAPS pipeline against the paper's
//! kernels, crossing every module boundary (sampling → surrogate →
//! optimizer → trees → validation → codegen → baselines).

use mlkaps::kernels::blas3sim::{dix, Blas3Sim, FactKind};
use mlkaps::kernels::hardware::HardwareProfile;
use mlkaps::kernels::toy_sum::ToySum;
use mlkaps::kernels::Kernel;
use mlkaps::optimizer::nsga2::Nsga2Params;
use mlkaps::pipeline::evaluate::SpeedupMap;
use mlkaps::pipeline::expert::ExpertModel;
use mlkaps::pipeline::{Mlkaps, MlkapsConfig, SamplerChoice};
use mlkaps::surrogate::gbdt::GbdtParams;

fn small_config(samples: usize, seed: u64) -> MlkapsConfig {
    MlkapsConfig {
        total_samples: samples,
        batch_size: 250,
        sampler: SamplerChoice::GaAdaptive,
        gbdt: GbdtParams { n_trees: 120, ..Default::default() },
        ga: Nsga2Params { pop_size: 24, generations: 20, ..Default::default() },
        opt_grid: 10,
        tree_depth: 8,
        threads: 4,
        seed,
    }
}

#[test]
fn dgetrf_spr_beats_reference_with_modest_budget() {
    let kernel = Blas3Sim::new(FactKind::Lu, HardwareProfile::spr(), 101);
    let model = Mlkaps::new(small_config(2_500, 1)).tune(&kernel);
    let map = SpeedupMap::build(&kernel, 12, &|i| model.predict(i));
    let s = map.summary();
    assert!(s.geomean > 1.0, "geomean {s}");
    assert!(s.frac_progressions > 0.25, "{s}"); // paper needs 30k samples for 85%
}

#[test]
fn knm_blind_spot_is_found_by_tuning() {
    // The paper's key qualitative finding (Fig 9c): at (4500, 1600) the
    // expert reference is catastrophically wrong on KNM and the tuner
    // must find a much faster configuration.
    let kernel = Blas3Sim::new(FactKind::Lu, HardwareProfile::knm(), 102);
    let model = Mlkaps::new(small_config(3_000, 2)).tune(&kernel);
    let input = [4500.0, 1600.0];
    let t_tuned = kernel.eval_true(&input, &model.predict(&input));
    let t_ref = kernel.eval_true(&input, &kernel.reference_design(&input).unwrap());
    assert!(
        t_ref / t_tuned > 1.8,
        "blind spot speedup only x{:.2}",
        t_ref / t_tuned
    );
    // And the tuner must have fixed the decomposition choice.
    let d = model.predict(&input);
    assert_ne!(
        d[dix::DECOMP], 1.0,
        "row-1d is the planted blind-spot mistake; the tuner kept it"
    );
}

#[test]
fn architectures_get_different_trees() {
    // §5.3: "the resulting design configurations ... are not the same for
    // the two architectures, showcasing that MLKAPS adapts".
    let knm = Blas3Sim::new(FactKind::Lu, HardwareProfile::knm(), 103);
    let spr = Blas3Sim::new(FactKind::Lu, HardwareProfile::spr(), 103);
    let m_knm = Mlkaps::new(small_config(1_500, 3)).tune(&knm);
    let m_spr = Mlkaps::new(small_config(1_500, 3)).tune(&spr);
    let diff = (0..8).filter(|&g| {
        let inputs = [[1500.0, 4500.0], [3000.0, 3000.0], [4500.0, 1500.0]];
        inputs.iter().any(|i| m_knm.predict(i)[g] != m_spr.predict(i)[g])
    });
    assert!(diff.count() >= 2, "trees should differ across architectures");
}

#[test]
fn c_codegen_of_real_tree_is_well_formed() {
    let kernel = ToySum::new(104);
    let model = Mlkaps::new(small_config(400, 4)).tune(&kernel);
    let c = model.trees.to_c();
    assert!(c.contains("double mlkaps_pick_T(double n, double m)"));
    assert!(c.contains("mlkaps_predict_config"));
    assert_eq!(c.matches('{').count(), c.matches('}').count());
    // Every leaf returns a valid thread count.
    for line in c.lines().filter(|l| l.trim_start().starts_with("return")) {
        let v: f64 = line
            .trim()
            .trim_start_matches("return ")
            .trim_end_matches(';')
            .parse()
            .unwrap_or(f64::NAN);
        if line.contains("out[") {
            continue;
        }
        assert!((1.0..=64.0).contains(&v) || v == 0.0, "leaf {line}");
    }
}

#[test]
fn expert_combination_beats_both_parents_on_grid() {
    let kernel = Blas3Sim::new(FactKind::Qr, HardwareProfile::spr(), 105);
    let model = Mlkaps::new(small_config(1_200, 5)).tune(&kernel);
    let expert = ExpertModel::combine(&kernel, &model, 3, 4);
    // On the optimization-grid inputs the expert choice must be at least
    // as good (within noise) as BOTH the reference and the MLKAPS tree.
    let mut worse = 0;
    for input in &model.grid.inputs {
        let t_e = kernel.eval_true(input, &expert.predict(input));
        let t_r =
            kernel.eval_true(input, &kernel.reference_design(input).unwrap());
        if t_e > t_r * 1.12 {
            worse += 1;
        }
    }
    let frac = worse as f64 / model.grid.inputs.len() as f64;
    assert!(frac < 0.15, "expert worse than reference on {frac:.0}% of grid");
}

#[test]
fn pipeline_survives_nan_objectives() {
    // Failure injection: a kernel that sometimes returns NaN/inf (crashed
    // measurements) must not break the pipeline.
    struct Flaky(ToySum, std::sync::atomic::AtomicU64);
    impl Kernel for Flaky {
        fn name(&self) -> &str {
            "flaky"
        }
        fn input_space(&self) -> &mlkaps::ParamSpace {
            self.0.input_space()
        }
        fn design_space(&self) -> &mlkaps::ParamSpace {
            self.0.design_space()
        }
        fn eval(&self, input: &[f64], design: &[f64]) -> f64 {
            let k = self.1.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            match k % 29 {
                0 => f64::INFINITY, // timeout
                7 => 1e12,          // absurd outlier
                _ => self.0.eval(input, design),
            }
        }
        fn reference_design(&self, i: &[f64]) -> Option<Vec<f64>> {
            self.0.reference_design(i)
        }
    }
    let kernel = Flaky(ToySum::new(106), std::sync::atomic::AtomicU64::new(0));
    let model = Mlkaps::new(small_config(300, 6)).tune(&kernel);
    // Trees must still emit finite, valid designs.
    for input in kernel.input_space().grid(4) {
        let d = model.predict(&input);
        assert!(d.iter().all(|v| v.is_finite()));
        assert!((1.0..=64.0).contains(&d[0]));
    }
}

#[test]
fn run_record_json_is_parseable() {
    let kernel = ToySum::new(107);
    let model = Mlkaps::new(small_config(200, 7)).tune(&kernel);
    let json = model.dataset.to_json().to_string();
    let back = mlkaps::util::json::parse(&json).unwrap();
    let ds = mlkaps::Dataset::from_json(&back).unwrap();
    assert_eq!(ds.len(), model.dataset.len());
}
