//! Chaos suite for the distributed stage-3 cluster: every fault the
//! coordinator/worker protocol is designed to absorb — a worker killed
//! mid-shard, a lease expiring under a refused heartbeat, the same
//! shard uploaded twice, a coordinator killed and restarted, a merge
//! fault — must leave the merged checkpoint directory **byte-for-byte
//! identical** to a single-process `tune` that never faulted.
//!
//! Failpoints are process-global, so every test serializes on one
//! mutex; the suite lives in its own test binary so it never races the
//! other integration tests.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Duration;

use mlkaps::kernels::toy_sum::ToySum;
use mlkaps::optimizer::grid::optimize_grid_shard;
use mlkaps::optimizer::nsga2::{Nsga2, Nsga2Params};
use mlkaps::pipeline::checkpoint::PipelineRun;
use mlkaps::pipeline::{MlkapsConfig, SamplerChoice};
use mlkaps::runtime::cluster::cluster_protocol::ClusterRequest;
use mlkaps::runtime::cluster::{
    Coordinator, CoordinatorConfig, RunSpec, WorkerConfig, run_worker, spawn_workers,
};
use mlkaps::runtime::server::client::ServedClient;
use mlkaps::surrogate::LogSurrogate;
use mlkaps::surrogate::gbdt::{Gbdt, GbdtParams};
use mlkaps::util::failpoint;
use mlkaps::util::json::Value;

static GATE: Mutex<()> = Mutex::new(());

fn gate() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

const SEED: u64 = 91;
/// Small shards so the 16-point grid splits into 4 shards: enough for
/// real lease traffic without slowing the suite down.
const SHARD: usize = 4;

fn config() -> MlkapsConfig {
    MlkapsConfig {
        total_samples: 120,
        batch_size: 60,
        sampler: SamplerChoice::Lhs,
        gbdt: GbdtParams { n_trees: 20, ..Default::default() },
        ga: Nsga2Params { pop_size: 8, generations: 5, ..Default::default() },
        opt_grid: 4,
        tree_depth: 4,
        threads: 1,
        seed: SEED,
    }
}

fn tmp(name: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("mlkaps_chaos_cluster_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn pipeline_run(dir: &PathBuf) -> PipelineRun {
    let mut run = PipelineRun::new(config(), dir.clone());
    run.shard_size = SHARD;
    run
}

/// The unfaulted single-process reference this whole suite compares to.
fn reference(name: &str) -> BTreeMap<String, Vec<u8>> {
    let dir = tmp(name);
    pipeline_run(&dir).run(&ToySum::new(SEED)).expect("reference tune");
    snapshot(&dir)
}

fn start_coordinator(dir: &PathBuf, addr: &str, ttl: Duration) -> Coordinator {
    let cfg = CoordinatorConfig {
        addr: addr.to_string(),
        lease_ttl: ttl,
        ..Default::default()
    };
    Coordinator::start(pipeline_run(dir), Box::new(ToySum::new(SEED)), cfg)
        .expect("coordinator start")
}

fn snapshot(dir: &PathBuf) -> BTreeMap<String, Vec<u8>> {
    let mut files = BTreeMap::new();
    for entry in std::fs::read_dir(dir).expect("checkpoint dir readable").flatten() {
        if entry.file_type().map(|t| t.is_file()).unwrap_or(false) {
            files.insert(
                entry.file_name().to_string_lossy().into_owned(),
                std::fs::read(entry.path()).expect("checkpoint file readable"),
            );
        }
    }
    files
}

fn assert_identical(
    got: &BTreeMap<String, Vec<u8>>,
    want: &BTreeMap<String, Vec<u8>>,
    ctx: &str,
) {
    let got_names: Vec<_> = got.keys().collect();
    let want_names: Vec<_> = want.keys().collect();
    assert_eq!(got_names, want_names, "{ctx}: file sets differ");
    for (name, bytes) in want {
        assert_eq!(&got[name], bytes, "{ctx}: {name} differs from the single-process bytes");
    }
}

/// Raw protocol round trip against a coordinator (the tests' hand-
/// rolled worker: it can misbehave in ways the real one refuses to).
fn rpc(client: &mut ServedClient, req: &ClusterRequest, seq: &mut u64) -> Value {
    *seq += 1;
    let id = Value::Num(*seq as f64);
    client.send_json(&req.to_json(&id)).expect("send");
    client.recv_json(Some(&id)).expect("recv")
}

#[test]
fn cluster_is_byte_identical_to_single_process_at_1_2_4_workers() {
    let _g = gate();
    let want = reference("ref_counts");
    for workers in [1usize, 2, 4] {
        let dir = tmp(&format!("w{workers}"));
        let coord = start_coordinator(&dir, "127.0.0.1:0", Duration::from_secs(5));
        let handles = spawn_workers(&coord.local_display(), workers, 1);
        // Join before finish: workers exit on their next lease round
        // trip (Complete), which needs the coordinator still listening.
        assert!(coord.wait_complete(Duration::from_secs(120)), "shard drain timed out");
        for h in handles {
            h.join().expect("worker thread").expect("worker ok");
        }
        coord.finish(Duration::from_secs(10)).expect("merge");
        assert_identical(&snapshot(&dir), &want, &format!("{workers} workers"));
    }
}

#[test]
fn cluster_over_unix_socket_is_byte_identical() {
    let _g = gate();
    let want = reference("ref_unix");
    let dir = tmp("unix");
    let sock = std::env::temp_dir()
        .join(format!("mlkaps_cluster_{}.sock", std::process::id()));
    std::fs::remove_file(&sock).ok();
    let addr = format!("unix:{}", sock.display());
    let coord = start_coordinator(&dir, &addr, Duration::from_secs(5));
    assert_eq!(coord.local_display(), addr);
    let handles = spawn_workers(&coord.local_display(), 2, 1);
    assert!(coord.wait_complete(Duration::from_secs(120)), "shard drain timed out");
    for h in handles {
        h.join().expect("worker thread").expect("worker ok");
    }
    coord.finish(Duration::from_secs(10)).expect("merge");
    assert_identical(&snapshot(&dir), &want, "unix-socket cluster");
    assert!(!sock.exists(), "coordinator should unlink its socket on shutdown");
}

#[test]
fn killed_worker_mid_shard_is_reassigned_and_bytes_match() {
    let _g = gate();
    let want = reference("ref_kill");
    let dir = tmp("kill");
    // Short TTL so the dead worker's lease is reassigned quickly.
    let coord = start_coordinator(&dir, "127.0.0.1:0", Duration::from_millis(300));
    // The first worker to take a lease panics between lease and
    // compute — the distributed analogue of `kill -9` mid-shard.
    let fp = failpoint::arm_scoped("cluster.worker_shard=panic@0").unwrap();
    let handles = spawn_workers(&coord.local_display(), 2, 1);
    assert!(coord.wait_complete(Duration::from_secs(120)), "shard drain timed out");
    drop(fp);
    let mut panicked = 0;
    for h in handles {
        if h.join().is_err() {
            panicked += 1;
        }
    }
    coord.finish(Duration::from_secs(10)).expect("merge despite a dead worker");
    assert_eq!(panicked, 1, "exactly one worker should have died to the injected panic");
    assert_identical(&snapshot(&dir), &want, "worker killed mid-shard");
}

#[test]
fn lease_expiry_and_duplicate_upload_resolve_idempotently() {
    let _g = gate();
    let want = reference("ref_dup");
    let dir = tmp("dup");
    let coord = start_coordinator(&dir, "127.0.0.1:0", Duration::from_millis(100));
    let addr = coord.local_display();
    let mut seq = 0u64;

    // Worker "a" leases shard 0 and computes it, but never heartbeats.
    let mut a = ServedClient::connect_str(&addr).expect("connect a");
    let spec_resp = rpc(&mut a, &ClusterRequest::Spec, &mut seq);
    let spec = RunSpec::from_json(spec_resp.get("spec").expect("spec")).expect("spec parse");
    let lease = rpc(&mut a, &ClusterRequest::Lease { worker: "a".into() }, &mut seq);
    let shard = lease.get("shard").unwrap().as_usize().unwrap();
    let base = lease.get("base").unwrap().as_usize().unwrap();
    let count = lease.get("count").unwrap().as_usize().unwrap();
    assert_eq!((shard, base, count), (0, 0, SHARD));

    let stage2 = mlkaps::util::json::parse(&spec.stage2_text).expect("stage2 parse");
    let surrogate =
        LogSurrogate::new(Gbdt::from_json(stage2.get("payload").expect("payload")).unwrap());
    let inputs = spec.input_space.grid(spec.opt_grid);
    let ga = Nsga2::new(spec.ga.clone());
    let (designs, predicted) = optimize_grid_shard(
        &surrogate,
        &spec.design_space,
        &inputs[base..base + count],
        base,
        &ga,
        &[],
        1,
        spec.grid_seed,
    );

    // An armed heartbeat failpoint makes the coordinator refuse
    // renewal — exactly how a lease dies "under load".
    {
        let _hb = failpoint::arm_scoped("cluster.heartbeat=err").unwrap();
        let refused =
            rpc(&mut a, &ClusterRequest::Heartbeat { worker: "a".into(), shard }, &mut seq);
        assert_eq!(refused.get("ok").and_then(|o| o.as_bool()), Some(false));
    }
    std::thread::sleep(Duration::from_millis(250)); // TTL lapses

    // With the lease expired, worker "b" is handed the *same* shard.
    let mut b = ServedClient::connect_str(&addr).expect("connect b");
    let heartbeat =
        rpc(&mut a, &ClusterRequest::Heartbeat { worker: "a".into(), shard }, &mut seq);
    assert_eq!(heartbeat.get("renewed").and_then(|r| r.as_bool()), Some(false));
    let lease_b = rpc(&mut b, &ClusterRequest::Lease { worker: "b".into() }, &mut seq);
    assert_eq!(lease_b.get("shard").and_then(|s| s.as_usize()), Some(0));

    // Both workers upload the shard: first accepted, second an
    // idempotent duplicate (identical artifact fingerprint).
    let result = ClusterRequest::Result {
        worker: "b".into(),
        shard,
        base,
        designs: designs.clone(),
        predicted: predicted.clone(),
    };
    let first = rpc(&mut b, &result, &mut seq);
    assert_eq!(first.get("accepted").and_then(|x| x.as_bool()), Some(true));
    assert_eq!(first.get("duplicate").and_then(|x| x.as_bool()), Some(false));
    let result_a = ClusterRequest::Result {
        worker: "a".into(),
        shard,
        base,
        designs,
        predicted,
    };
    let second = rpc(&mut a, &result_a, &mut seq);
    assert_eq!(second.get("accepted").and_then(|x| x.as_bool()), Some(true));
    assert_eq!(second.get("duplicate").and_then(|x| x.as_bool()), Some(true));

    // A real worker finishes the remaining shards; the merged
    // directory still matches the unfaulted single-process bytes.
    let handles = spawn_workers(&addr, 1, 1);
    assert!(coord.wait_complete(Duration::from_secs(120)), "shard drain timed out");
    for h in handles {
        h.join().expect("worker thread").expect("worker ok");
    }
    coord.finish(Duration::from_secs(10)).expect("merge");
    assert_identical(&snapshot(&dir), &want, "expired lease + duplicate upload");
}

#[test]
fn coordinator_restart_resumes_from_the_persisted_ledger() {
    let _g = gate();
    let want = reference("ref_restart");
    let dir = tmp("restart");

    // First coordinator: one worker computes exactly 2 of 4 shards,
    // then the coordinator is stopped (a kill, minus the SIGKILL).
    let mut first = start_coordinator(&dir, "127.0.0.1:0", Duration::from_secs(5));
    let mut wcfg = WorkerConfig::new(first.local_display(), "partial");
    wcfg.max_shards = Some(2);
    let report = run_worker(&wcfg).expect("partial worker");
    assert_eq!(report.shards, 2);
    first.stop();
    drop(first);
    assert!(dir.join("cluster_ledger.json").exists(), "ledger persisted across restart");

    // Second coordinator: the ledger (cross-checked against the shard
    // bytes on disk) restores both finished shards — nothing is
    // re-leased or recomputed.
    let coord = start_coordinator(&dir, "127.0.0.1:0", Duration::from_secs(5));
    let (pending, leased, done, total) = coord.progress();
    assert_eq!(
        (pending, leased, done, total),
        (2, 0, 2, 4),
        "restart must resume leasing, not re-run completed shards"
    );
    let handles = spawn_workers(&coord.local_display(), 1, 1);
    assert!(coord.wait_complete(Duration::from_secs(120)), "shard drain timed out");
    for h in handles {
        h.join().expect("worker thread").expect("worker ok");
    }
    coord.finish(Duration::from_secs(10)).expect("merge after restart");
    assert!(!dir.join("cluster_ledger.json").exists(), "merge removes the ledger");
    assert_identical(&snapshot(&dir), &want, "coordinator restart");
}

#[test]
fn merge_fault_leaves_a_resumable_directory() {
    let _g = gate();
    let want = reference("ref_merge");
    let dir = tmp("merge");

    let coord = start_coordinator(&dir, "127.0.0.1:0", Duration::from_secs(5));
    let handles = spawn_workers(&coord.local_display(), 1, 1);
    assert!(coord.wait_complete(Duration::from_secs(120)), "shard drain timed out");
    for h in handles {
        h.join().expect("worker thread").expect("worker ok");
    }
    let fp = failpoint::arm_scoped("cluster.merge=err").unwrap();
    let err = coord.finish(Duration::from_secs(10)).expect_err("injected merge fault");
    assert!(err.contains("merge"), "unexpected error: {err}");
    drop(fp);
    assert!(dir.join("cluster_ledger.json").exists(), "faulted merge keeps the ledger");

    // A fresh coordinator finds every shard done and merges cleanly.
    let coord = start_coordinator(&dir, "127.0.0.1:0", Duration::from_secs(5));
    let (.., done, total) = coord.progress();
    assert_eq!((done, total), (4, 4));
    coord.finish(Duration::from_secs(10)).expect("clean merge on retry");
    assert_identical(&snapshot(&dir), &want, "merge retry");
}

#[test]
fn spooled_shard_survives_upload_faults_and_is_reoffered_on_reconnect() {
    let _g = gate();
    let want = reference("ref_spool");
    let dir = tmp("spool");
    let spool = tmp("spool_files");

    let coord = start_coordinator(&dir, "127.0.0.1:0", Duration::from_secs(5));
    let addr = coord.local_display();

    // Worker 1 computes one shard, but every upload attempt dies to the
    // injected cluster.upload fault (the coordinator might as well be
    // down): the result is spooled instead of thrown away, and the
    // shard still counts as computed.
    {
        let _fp = failpoint::arm_scoped("cluster.upload=err").unwrap();
        let mut wcfg = WorkerConfig::new(&addr, "spooler");
        wcfg.max_shards = Some(1);
        wcfg.spool_dir = Some(spool.clone());
        let report = run_worker(&wcfg).expect("spooling worker");
        assert_eq!(report.shards, 1, "the computed-but-unacknowledged shard counts");
        assert_eq!(report.respooled, 0);
    }
    let spooled = std::fs::read_dir(&spool).unwrap().flatten().count();
    assert_eq!(spooled, 1, "exactly one spool file persisted");

    // Worker 2, faults cleared, same spool dir: it re-offers the
    // spooled shard on reconnect (before taking any lease), then
    // finishes the remaining shards.
    let mut wcfg = WorkerConfig::new(&addr, "reofferer");
    wcfg.spool_dir = Some(spool.clone());
    let report = run_worker(&wcfg).expect("re-offering worker");
    assert_eq!(report.respooled, 1, "the spooled shard was re-offered and accepted");
    assert_eq!(report.shards, 3, "only the three never-computed shards were leased");
    assert_eq!(
        std::fs::read_dir(&spool).unwrap().flatten().count(),
        0,
        "an accepted re-offer must delete its spool file"
    );

    assert!(coord.wait_complete(Duration::from_secs(120)), "shard drain timed out");
    coord.finish(Duration::from_secs(10)).expect("merge");
    assert_identical(&snapshot(&dir), &want, "spooled shard re-offer");
    std::fs::remove_dir_all(&spool).ok();
}
