//! Chaos suite for the `mlkaps served` daemon: adversarial peers
//! (truncated frames, oversized length announcements, non-UTF-8 bytes,
//! unknown verbs, slow-loris stalls), injected socket/batcher/reload
//! faults, and queue saturation — while **well-behaved clients keep
//! getting bit-identical decisions with zero errors** and the recovery
//! counters (`restarts`, `sheds`, `timeouts`, `malformed_frames`,
//! `conn_panics`) observably move.
//!
//! Failpoints are process-global, so every test serializes on one
//! mutex; the suite lives in its own test binary so armed faults never
//! leak into the integration tests.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use mlkaps::config::space::{ParamDef, ParamSpace};
use mlkaps::dtree::DesignTrees;
use mlkaps::runtime::server::client::ServedClient;
use mlkaps::runtime::server::daemon::{Daemon, DaemonConfig};
use mlkaps::runtime::server::protocol::{read_frame, write_frame};
use mlkaps::runtime::server::reload::ReloadableBundle;
use mlkaps::runtime::server::ServedRegistry;
use mlkaps::runtime::serving::TreeBundle;
use mlkaps::util::failpoint;
use mlkaps::util::json::{self, Value};
use mlkaps::util::rng::Rng;

/// Failpoint state is process-global: tests take this before arming.
/// Poison-tolerant so one failed test doesn't wedge the rest.
static GATE: Mutex<()> = Mutex::new(());

fn gate() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// A cheap tuning-shaped bundle (no pipeline run needed: the chaos
/// suite tests the daemon, not the tuner).
fn trees() -> DesignTrees {
    let input = ParamSpace::new(vec![
        ParamDef::float("n", 64.0, 8192.0),
        ParamDef::float("m", 64.0, 8192.0),
    ]);
    let design = ParamSpace::new(vec![
        ParamDef::int("threads", 1, 64),
        ParamDef::categorical("variant", &["row", "col", "tile"]),
        ParamDef::boolean("prefetch"),
    ]);
    let grid = input.grid(12);
    let designs: Vec<Vec<f64>> = grid
        .iter()
        .map(|p| {
            let size = p[0] * p[1];
            vec![
                (size.sqrt() / 128.0).round().clamp(1.0, 64.0),
                if p[1] > 2.0 * p[0] { 2.0 } else { 1.0 },
                if size > 1e6 { 1.0 } else { 0.0 },
            ]
        })
        .collect();
    DesignTrees::fit(&grid, &designs, &input, &design, 8)
}

/// A daemon serving `toy`, plus an identical in-process reference
/// bundle for bit-identity assertions.
fn boot(cfg: DaemonConfig) -> (Daemon, TreeBundle) {
    let t = trees();
    let reference = TreeBundle::from_trees(t.clone()).unwrap();
    let mut reg = ServedRegistry::new(None);
    reg.register_bundle("toy", TreeBundle::from_trees(t).unwrap()).unwrap();
    (Daemon::start(reg, cfg).unwrap(), reference)
}

fn cfg() -> DaemonConfig {
    DaemonConfig {
        addr: "127.0.0.1:0".into(),
        batch_max: 64,
        // Wider than the 200µs production default so concurrent test
        // clients reliably coalesce on a single-core CI runner.
        batch_window: Duration::from_millis(1),
        poll_interval: Duration::from_secs(3600), // nothing watched
        threads: 1,
        queue_capacity: 1024,
        ..Default::default()
    }
}

fn raw(addr: std::net::SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.set_nodelay(true).ok();
    s
}

fn read_json_frame(s: &mut TcpStream) -> Value {
    let payload = read_frame(s).unwrap().expect("daemon closed before responding");
    json::parse(std::str::from_utf8(&payload).unwrap()).unwrap()
}

fn counter(stats: &Value, field: &str) -> u64 {
    stats
        .get(field)
        .and_then(Value::as_f64)
        .unwrap_or_else(|| panic!("STATS missing {field}")) as u64
}

/// Tentpole acceptance: five kinds of adversarial peers hammer the
/// daemon while well-behaved clients run — the good clients see zero
/// errors and decisions bit-identical to in-process `decide`, every
/// adversary is answered or disconnected (never hung on), the
/// malformed/timeout counters account for them, and the daemon drains
/// cleanly afterwards.
#[test]
fn adversarial_peers_never_perturb_well_behaved_clients() {
    let _g = gate();
    let (mut daemon, reference) =
        boot(DaemonConfig { read_timeout: Duration::from_millis(200), ..cfg() });
    let addr = daemon.local_addr();

    const CLIENTS: usize = 3;
    const PER_CLIENT: usize = 150;
    std::thread::scope(|scope| {
        let mut good = Vec::new();
        for t in 0..CLIENTS {
            let reference = &reference;
            good.push(scope.spawn(move || {
                let mut client = ServedClient::connect(addr).unwrap();
                let mut rng = Rng::new(9000 + t as u64);
                for _ in 0..PER_CLIENT {
                    let q = vec![rng.uniform(64.0, 8192.0), rng.uniform(64.0, 8192.0)];
                    let d = client.decide("toy", &q, None).unwrap();
                    assert_eq!(
                        d.values,
                        reference.decide(&q),
                        "served decision diverged under adversarial load for {q:?}"
                    );
                }
            }));
        }

        // Adversary 1: a frame truncated mid-payload (announces 256
        // bytes, sends 10, hangs up). Counted malformed, connection
        // dropped, nobody else affected.
        let mut s = raw(addr);
        s.write_all(&256u32.to_be_bytes()).unwrap();
        s.write_all(b"0123456789").unwrap();
        drop(s);

        // Adversary 2: a valid binary connection that then announces an
        // absurd 4 GiB frame. The daemon answers with a structured
        // error *without attempting the allocation*, then closes.
        let mut s = raw(addr);
        write_frame(&mut s, br#"{"op":"ping"}"#).unwrap();
        assert_eq!(read_json_frame(&mut s).get("ok"), Some(&Value::Bool(true)));
        s.write_all(&u32::MAX.to_be_bytes()).unwrap();
        let resp = read_json_frame(&mut s);
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)));
        let err = resp.get("error").and_then(Value::as_str).unwrap();
        assert!(err.contains("exceeds"), "oversized error: {err}");
        let mut rest = Vec::new();
        assert_eq!(s.read_to_end(&mut rest).unwrap(), 0, "connection must close");

        // Adversary 3: a well-framed payload that is not UTF-8. Gets an
        // error response and the connection *survives* — framing is
        // still intact.
        let mut s = raw(addr);
        write_frame(&mut s, &[0xC3, 0x28, 0xFF]).unwrap();
        let resp = read_json_frame(&mut s);
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)));
        write_frame(&mut s, br#"{"op":"ping"}"#).unwrap();
        assert_eq!(
            read_json_frame(&mut s).get("ok"),
            Some(&Value::Bool(true)),
            "connection must survive a malformed-payload request"
        );
        drop(s);

        // Adversary 4: text-mode gibberish verb, then a valid PING on
        // the same connection.
        let mut s = raw(addr);
        s.write_all(b"EXPLODE\n").unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = json::parse(&line).unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)));
        s.write_all(b"PING\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(json::parse(&line).unwrap().get("ok"), Some(&Value::Bool(true)));
        drop(s);

        // Adversary 5: a text line that never ends (1 MiB + 1 bytes, no
        // newline). Answered with the cap error, then disconnected —
        // the buffer never grows past the cap.
        let mut s = raw(addr);
        let big = vec![b'a'; (1 << 20) + 1];
        let _ = s.write_all(&big);
        let mut reader = BufReader::new(s);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = json::parse(&line).unwrap();
        let err = resp.get("error").and_then(Value::as_str).unwrap();
        assert!(err.contains("1 MiB cap"), "cap error: {err}");

        // Adversary 6: slow-loris — one byte, then silence longer than
        // the 200ms read timeout. The daemon hangs up on *it*, not on
        // anyone else.
        let mut s = raw(addr);
        s.write_all(b"P").unwrap();
        std::thread::sleep(Duration::from_millis(600));
        let mut rest = Vec::new();
        assert_eq!(s.read_to_end(&mut rest).unwrap(), 0, "loris must be disconnected");

        for h in good {
            h.join().unwrap();
        }
    });

    // The books balance: every adversary is in a counter, the good
    // clients are not.
    let mut control = ServedClient::connect(addr).unwrap();
    let stats = control.stats().unwrap();
    assert!(counter(&stats, "malformed_frames") >= 5, "stats: {}", stats.to_string());
    assert!(counter(&stats, "timeouts") >= 1, "stats: {}", stats.to_string());
    let toy = stats.get("kernels").and_then(|k| k.get("toy")).unwrap();
    assert_eq!(counter(toy, "errors"), 0, "well-behaved clients must see zero errors");
    assert!(counter(toy, "requests") >= (CLIENTS * PER_CLIENT) as u64);

    // And the daemon still drains cleanly after all of that.
    control.drain().unwrap();
    daemon.wait();
}

/// Queue saturation + a persistently panicking batcher: requests are
/// shed with a structured `overloaded` + `retry_after_ms` response
/// (never a blocked producer, never a hang), the supervisor restarts
/// the batcher with backoff, and once the fault clears the daemon
/// serves bit-identical decisions again.
#[test]
fn overload_sheds_with_retry_hint_and_batcher_restarts_heal() {
    let _g = gate();
    let (mut daemon, reference) = boot(DaemonConfig {
        queue_capacity: 1,
        batch_max: 1,
        ..cfg()
    });
    let addr = daemon.local_addr();
    let q = vec![1000.0, 2000.0];

    let armed = failpoint::arm_scoped("batcher.flush=panic").unwrap();
    let stop = AtomicBool::new(false);
    let mut saw_overloaded = false;
    std::thread::scope(|scope| {
        // Hammers keep the 1-slot queue occupied so concurrent pushes
        // shed. Their requests die in panicking flushes — each gets an
        // explicit dropped/overloaded error response, never a hang.
        let mut hammers = Vec::new();
        for _ in 0..2 {
            let stop = &stop;
            hammers.push(scope.spawn(move || {
                let mut client = ServedClient::connect(addr).unwrap();
                while !stop.load(Ordering::Relaxed) {
                    let _ = client.decide("toy", &[500.0, 600.0], None);
                }
            }));
        }

        // A raw text-mode observer: hammer decides until one response
        // is the structured shed.
        let s = raw(addr);
        let mut reader = BufReader::new(s.try_clone().unwrap());
        let mut writer = s;
        let deadline = Instant::now() + Duration::from_secs(60);
        while Instant::now() < deadline {
            writer.write_all(b"{\"kernel\":\"toy\",\"input\":[1000,2000]}\n").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let resp = json::parse(&line).unwrap();
            assert_ne!(
                resp.get("ok"),
                Some(&Value::Bool(true)),
                "no decide can succeed while every flush panics"
            );
            if resp.get("overloaded") == Some(&Value::Bool(true)) {
                let hint = resp.get("retry_after_ms").and_then(Value::as_f64).unwrap();
                assert!(hint >= 1.0, "retry_after_ms hint must be usable: {}", resp.to_string());
                let err = resp.get("error").and_then(Value::as_str).unwrap();
                assert!(err.contains("overloaded"), "{err}");
                saw_overloaded = true;
                break;
            }
        }
        stop.store(true, Ordering::Relaxed);
        for h in hammers {
            h.join().unwrap();
        }
    });
    assert!(saw_overloaded, "queue saturation never produced a structured shed");
    drop(armed); // heal the batcher

    // Recovery: within a few supervisor backoff windows the daemon
    // answers again, bit-identical to the in-process reference.
    let mut client = ServedClient::connect(addr).unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    let decision = loop {
        match client.decide("toy", &q, None) {
            Ok(d) => break d,
            Err(_) if Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(50))
            }
            Err(e) => panic!("daemon never recovered after disarm: {e}"),
        }
    };
    assert_eq!(decision.values, reference.decide(&q), "post-recovery decision diverged");

    let stats = client.stats().unwrap();
    assert!(counter(&stats, "restarts") >= 1, "stats: {}", stats.to_string());
    assert!(counter(&stats, "sheds") >= 1, "stats: {}", stats.to_string());
    client.shutdown().unwrap();
    daemon.wait();
}

/// A panicking connection handler (and a transiently failing accept)
/// kill exactly one connection each: the next client is served, and
/// only `conn_panics` moves.
#[test]
fn connection_panics_and_accept_faults_stay_isolated() {
    let _g = gate();
    let (mut daemon, _reference) = boot(cfg());
    let addr = daemon.local_addr();
    ServedClient::connect(addr).unwrap().ping().unwrap();

    {
        let _armed = failpoint::arm_scoped("daemon.conn=panic@0").unwrap();
        let mut victim = raw(addr);
        let mut buf = Vec::new();
        assert_eq!(
            victim.read_to_end(&mut buf).unwrap(),
            0,
            "the panicking handler's connection must just close"
        );
    }
    ServedClient::connect(addr).unwrap().ping().expect("daemon must survive a conn panic");

    {
        let _armed = failpoint::arm_scoped("daemon.accept=err@0").unwrap();
        // TCP-accepted by the kernel, then dropped by the armed accept
        // loop: the client sees an immediate close, not a hang.
        let mut victim = ServedClient::connect(addr).unwrap();
        assert!(victim.ping().is_err(), "the dropped connection must error out");
    }

    let mut client = ServedClient::connect(addr).unwrap();
    client.ping().expect("daemon must survive an accept fault");
    let stats = client.stats().unwrap();
    assert_eq!(counter(&stats, "conn_panics"), 1, "stats: {}", stats.to_string());
    client.shutdown().unwrap();
    daemon.wait();
}

/// Injected read/write socket faults close only their own connection,
/// mid-request, and the client sees an explicit error — the next
/// connection works.
#[test]
fn injected_socket_faults_close_one_connection_cleanly() {
    let _g = gate();
    let (mut daemon, _reference) = boot(cfg());
    let addr = daemon.local_addr();

    // Read fault (one-shot): the armed connection answers its in-flight
    // request, then observes the injected EOF and closes.
    let mut a = ServedClient::connect(addr).unwrap();
    a.ping().unwrap();
    {
        let _armed = failpoint::arm_scoped("daemon.read=eof@0").unwrap();
        a.ping().expect("the request before the injected EOF still answers");
        let err = a.ping().expect_err("the connection must be closed after the EOF");
        // Clean FIN ("closed the connection") or an RST if the close
        // races our write — explicit either way, never a hang.
        assert!(
            err.contains("closed the connection")
                || err.contains("reset")
                || err.contains("pipe"),
            "{err}"
        );
    }
    ServedClient::connect(addr).unwrap().ping().expect("next connection must work");

    // Write fault (one-shot): the response is dropped and the
    // connection closes; the client gets an explicit mid-request error.
    let mut b = ServedClient::connect(addr).unwrap();
    b.ping().unwrap();
    {
        let _armed = failpoint::arm_scoped("daemon.write=err@0").unwrap();
        let err = b.ping().expect_err("the faulted write must drop the response");
        assert!(
            err.contains("closed the connection")
                || err.contains("reset")
                || err.contains("pipe"),
            "{err}"
        );
    }

    let mut client = ServedClient::connect(addr).unwrap();
    client.ping().expect("daemon must survive socket faults");
    client.shutdown().unwrap();
    daemon.wait();
}

/// An injected enqueue fault surfaces as an explicit error response to
/// exactly that request; the connection and the daemon keep working.
#[test]
fn injected_enqueue_fault_errors_one_request() {
    let _g = gate();
    let (mut daemon, reference) = boot(cfg());
    let addr = daemon.local_addr();
    let q = vec![4096.0, 128.0];

    let mut client = ServedClient::connect(addr).unwrap();
    {
        let _armed = failpoint::arm_scoped("batcher.enqueue=err@0").unwrap();
        let err = client.decide("toy", &q, None).expect_err("armed enqueue must fail");
        assert!(err.contains("injected"), "{err}");
    }
    let d = client.decide("toy", &q, None).expect("the very next request succeeds");
    assert_eq!(d.values, reference.decide(&q));
    client.shutdown().unwrap();
    daemon.wait();
}

/// A hot-reload poll that faults counts a reload error and keeps the
/// old epoch serving — injected faults and real ones (missing
/// checkpoint) take the same path.
#[test]
fn reload_poll_faults_keep_the_old_epoch_serving() {
    let _g = gate();
    let dir = std::env::temp_dir()
        .join(format!("mlkaps_chaos_reload_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();

    let bundle = TreeBundle::from_trees(trees()).unwrap();
    let q = vec![777.0, 3333.0];
    let want = bundle.decide(&q);
    let slot = ReloadableBundle::new(bundle, Some(dir.clone()));

    {
        let _armed = failpoint::arm_scoped("reload.poll=err").unwrap();
        let err = slot.poll().expect_err("armed poll must fail");
        assert!(err.contains("injected"), "{err}");
        assert_eq!(slot.reload_errors(), 1);
        assert_eq!(slot.get().decide(&q), want, "old epoch must keep serving");
    }

    // Disarmed, the poll still fails — but now for the real reason (no
    // checkpoint in the watched dir), through the same counter.
    let err = slot.poll().expect_err("empty dir cannot reload");
    assert!(!err.contains("injected"), "{err}");
    assert_eq!(slot.reload_errors(), 2);
    assert_eq!(slot.get().decide(&q), want);
    std::fs::remove_dir_all(&dir).ok();
}
