//! Golden-snapshot tests for the deployed-bundle formats: the JSON tree
//! serialization (`dtree::serialize`) and the generated C/Rust source
//! (`dtree::codegen`). The expected outputs are checked in under
//! `tests/golden/`, so *any* format drift fails loudly here instead of
//! silently corrupting bundles already deployed in the field.
//!
//! If a change is intentional, bump the relevant format/version marker
//! and regenerate the snapshots with `MLKAPS_UPDATE_GOLDEN=1 cargo test`.

use std::path::PathBuf;

use mlkaps::config::space::{ParamDef, ParamSpace};
use mlkaps::dtree::{
    to_c_function, to_rust_function, Cart, CartNode, CartParams, DesignTrees, TaskKind,
};
use mlkaps::util::json::parse;

/// A hand-built fixture model (no fitting, so the snapshot can never
/// drift through training-side changes): two float inputs, one int
/// design parameter, a depth-2 tree with exactly representable values.
fn fixture_model() -> DesignTrees {
    let input_space = ParamSpace::new(vec![
        ParamDef::float("n", 0.0, 10.0),
        ParamDef::float("m", -5.0, 5.0),
    ]);
    let design_space = ParamSpace::new(vec![ParamDef::int("threads", 1, 8)]);
    let tree = Cart {
        params: CartParams { max_depth: 3, min_samples_leaf: 1, task: TaskKind::Regression },
        nodes: vec![
            CartNode::Split { feat: 0, threshold: 2.5, left: 1, right: 2 },
            CartNode::Leaf { value: 1.0 },
            CartNode::Split { feat: 1, threshold: -0.5, left: 3, right: 4 },
            CartNode::Leaf { value: 2.5 },
            CartNode::Leaf { value: 10.0 },
        ],
    };
    DesignTrees { trees: vec![tree], input_space, design_space }
}

/// Compare produced output against a checked-in snapshot (trailing
/// whitespace ignored). `MLKAPS_UPDATE_GOLDEN=1` regenerates the file.
fn check_golden(name: &str, produced: &str) {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden")
        .join(name);
    if std::env::var("MLKAPS_UPDATE_GOLDEN").is_ok_and(|v| v == "1") {
        std::fs::write(&path, format!("{}\n", produced.trim_end())).unwrap();
    }
    let want = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!("missing golden '{name}' ({e}); regenerate with MLKAPS_UPDATE_GOLDEN=1")
    });
    assert_eq!(
        produced.trim_end(),
        want.trim_end(),
        "golden snapshot '{name}' drifted — deployed bundles would stop \
         round-tripping; if the change is intentional, bump the format \
         marker and regenerate with MLKAPS_UPDATE_GOLDEN=1"
    );
}

#[test]
fn serialized_model_matches_golden_json() {
    check_golden("model.json.golden", &fixture_model().to_json().to_pretty());
}

#[test]
fn golden_json_loads_and_predicts_like_the_fixture() {
    // The checked-in snapshot itself must stay loadable: this is the
    // "bundle already deployed in the field" compatibility check.
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/model.json.golden");
    let text = std::fs::read_to_string(path).unwrap();
    let loaded = DesignTrees::from_json(&parse(&text).unwrap()).unwrap();
    let fixture = fixture_model();
    for q in [
        [0.0, 0.0],
        [2.5, -0.5],
        [2.6, -0.5],
        [2.6, -0.4],
        [9.0, 4.0],
        [f64::NAN, 1.0],
    ] {
        assert_eq!(loaded.predict(&q), fixture.predict(&q), "{q:?}");
    }
}

#[test]
fn generated_c_matches_golden_source() {
    check_golden("model.c.golden", &fixture_model().to_c());
}

#[test]
fn generated_rust_matches_golden_source() {
    let m = fixture_model();
    let names: Vec<String> = vec!["n".into(), "m".into()];
    check_golden(
        "tree.rs.golden",
        &to_rust_function(&m.trees[0], "pick_threads", &names),
    );
}

#[test]
fn c_and_rust_emitters_stay_in_sync_on_the_fixture() {
    // Structural invariant across both emitters: same thresholds, same
    // leaf constants, balanced braces (guards the goldens themselves).
    let m = fixture_model();
    let names: Vec<String> = vec!["n".into(), "m".into()];
    let c = to_c_function(&m.trees[0], "pick_threads", &names);
    let r = to_rust_function(&m.trees[0], "pick_threads", &names);
    for needle in ["2.5", "-0.5", "1.0", "10.0"] {
        assert!(c.contains(needle), "C source lost {needle}");
        assert!(r.contains(needle), "Rust source lost {needle}");
    }
    assert_eq!(c.matches('{').count(), c.matches('}').count());
    assert_eq!(r.matches('{').count(), r.matches('}').count());
}
