//! End-to-end serving tests: the full 4-stage pipeline run twice on
//! toy_sum (and once resumed from a stage-2 checkpoint) must produce
//! **byte-identical** tree bundles, and the serving runtime loaded from
//! those bundles must decide identically to the in-memory tuned model —
//! scalar and batched, at every thread count.
//!
//! Sampling runs with `threads: 1` so fresh runs are comparable (the
//! simulator's measurement noise is drawn from a shared call counter;
//! see `integration_checkpoint.rs`). Stages 2-4 are deterministic for a
//! fixed stage-1 checkpoint regardless of thread count.

use std::path::PathBuf;

use mlkaps::kernels::toy_sum::ToySum;
use mlkaps::optimizer::nsga2::Nsga2Params;
use mlkaps::pipeline::checkpoint::{PipelineRun, Stage};
use mlkaps::pipeline::{MlkapsConfig, SamplerChoice};
use mlkaps::runtime::serving::{KernelRegistry, TreeBundle};
use mlkaps::surrogate::gbdt::GbdtParams;
use mlkaps::util::rng::Rng;

fn config(seed: u64) -> MlkapsConfig {
    MlkapsConfig {
        total_samples: 200,
        batch_size: 100,
        sampler: SamplerChoice::Lhs,
        gbdt: GbdtParams { n_trees: 40, ..Default::default() },
        ga: Nsga2Params { pop_size: 12, generations: 8, ..Default::default() },
        opt_grid: 5,
        tree_depth: 4,
        threads: 1,
        seed,
    }
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("mlkaps_serve_it_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn bundle_bytes(dir: &PathBuf) -> Vec<u8> {
    std::fs::read(dir.join("stage4_trees.json")).unwrap()
}

#[test]
fn pipeline_reruns_and_stage2_resume_produce_byte_identical_bundles() {
    let dir_a = tmp_dir("a");
    let dir_b = tmp_dir("b");
    let dir_c = tmp_dir("c");

    // Run 1: uninterrupted.
    let run_a = PipelineRun::new(config(60), dir_a.clone());
    let model_a = run_a.run(&ToySum::new(60)).unwrap().model;

    // Run 2: fresh directory, same config + seed.
    PipelineRun::new(config(60), dir_b.clone()).run(&ToySum::new(60)).unwrap();

    // Run 3: "killed" after the surrogate stage, then resumed.
    let run_c = PipelineRun::new(config(60), dir_c.clone());
    run_c.run_prefix(&ToySum::new(60), Stage::Surrogate).unwrap();
    let resumed = run_c.run(&ToySum::new(60)).unwrap();
    assert!(resumed.stages[0].loaded && resumed.stages[1].loaded);
    assert!(!resumed.stages[2].loaded && !resumed.stages[3].loaded);

    // Byte-identical deployed artifacts across all three runs.
    let a = bundle_bytes(&dir_a);
    assert_eq!(a, bundle_bytes(&dir_b), "fresh rerun produced different bundle bytes");
    assert_eq!(a, bundle_bytes(&dir_c), "stage-2 resume produced different bundle bytes");
    assert_eq!(
        std::fs::read(dir_a.join("stage3_grid.json")).unwrap(),
        std::fs::read(dir_c.join("stage3_grid.json")).unwrap(),
        "resumed grid artifact diverged"
    );

    // Serve from the checkpoint: bit-identical to the in-memory model,
    // scalar and batched, across thread counts.
    let bundle = TreeBundle::load_checkpoint_dir(&dir_a).unwrap();
    assert_eq!(bundle.kernel(), Some("toy-sum"));
    assert!(bundle.fingerprint().is_some());

    let mut rng = Rng::new(7);
    let rows: Vec<Vec<f64>> = (0..3000)
        .map(|_| vec![rng.uniform(64.0, 8192.0), rng.uniform(64.0, 8192.0)])
        .collect();
    let want: Vec<Vec<f64>> = rows.iter().map(|r| model_a.predict(r)).collect();
    let scalar: Vec<Vec<f64>> = rows.iter().map(|r| bundle.decide(r)).collect();
    assert_eq!(scalar, want, "served decisions differ from the tuned model");
    for threads in [1usize, 2, 8, 0] {
        assert_eq!(
            bundle.decide_batch(&rows, threads),
            want,
            "decide_batch diverged at threads={threads}"
        );
    }

    // The in-memory bundle built straight from the tuned model agrees too.
    let mem_bundle = model_a.serving_bundle().unwrap();
    assert_eq!(mem_bundle.decide(&rows[0]), want[0]);

    for d in [&dir_a, &dir_b, &dir_c] {
        std::fs::remove_dir_all(d).ok();
    }
}

#[test]
fn registry_serves_multiple_checkpoint_dirs() {
    let dir_x = tmp_dir("reg_x");
    let dir_y = tmp_dir("reg_y");
    PipelineRun::new(config(61), dir_x.clone()).run(&ToySum::new(61)).unwrap();
    PipelineRun::new(config(62), dir_y.clone()).run(&ToySum::new(62)).unwrap();

    let mut reg = KernelRegistry::new();
    let name_x = reg.load_dir(&dir_x, None).unwrap();
    assert_eq!(name_x, "toy-sum", "default name must come from the checkpoint meta");
    // A second dir of the same kernel must not silently shadow the first.
    let err = reg.load_dir(&dir_y, None).unwrap_err();
    assert!(err.contains("already registered"), "{err}");
    reg.load_dir(&dir_y, Some("toy-sum-alt")).unwrap();
    assert_eq!(reg.names(), vec!["toy-sum", "toy-sum-alt"]);

    let q = vec![1000.0, 4000.0];
    let a = reg.decide("toy-sum", &q).unwrap();
    let b = reg.decide("toy-sum-alt", &q).unwrap();
    assert_eq!(a.len(), 1);
    assert_eq!(b.len(), 1);
    assert_eq!(reg.decide_batch("toy-sum", &[q.clone()], 2).unwrap()[0], a);
    assert!(reg.decide("missing", &q).is_err());

    // Repeated traffic on the same input is served from the memo cache.
    for _ in 0..10 {
        assert_eq!(reg.decide("toy-sum", &q).unwrap(), a);
    }
    let counters = reg.get("toy-sum").unwrap().cache_counters();
    assert!(counters.hits() >= 10, "hits={}", counters.hits());

    std::fs::remove_dir_all(&dir_x).ok();
    std::fs::remove_dir_all(&dir_y).ok();
}

#[test]
fn tampered_checkpoint_is_refused_by_the_loader() {
    let dir = tmp_dir("tamper");
    PipelineRun::new(config(63), dir.clone()).run(&ToySum::new(63)).unwrap();

    // Corrupt the grid artifact the trees were fit on: the stage-4
    // upstream hash must make the serving loader refuse the bundle.
    let p = dir.join("stage3_grid.json");
    let mut text = std::fs::read_to_string(&p).unwrap();
    text.push('\n');
    std::fs::write(&p, text).unwrap();
    let err = TreeBundle::load_checkpoint_dir(&dir).unwrap_err();
    assert!(err.contains("different optimization grid"), "{err}");

    std::fs::remove_dir_all(&dir).ok();
}
