//! END-TO-END driver: all three layers composed on a real workload.
//!
//! L1 (Pallas blocked-LU kernels) -> L2 (JAX blocked-LU graph) -> AOT HLO
//! text artifacts -> L3 (this Rust coordinator) loads them via PJRT,
//! MEASURES real wall-clock times, runs the full MLKAPS pipeline on those
//! measurements, and emits a decision tree mapping matrix size -> best
//! (block, tile).
//!
//! Requires `make artifacts` first. Run:
//! `cargo run --release --example tune_pallas_lu`
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use std::sync::Arc;

use mlkaps::kernels::pallas_lu::PallasLu;
use mlkaps::kernels::Kernel;
use mlkaps::optimizer::nsga2::Nsga2Params;
use mlkaps::pipeline::{Mlkaps, MlkapsConfig, SamplerChoice};
use mlkaps::runtime::LuRuntime;
use mlkaps::surrogate::gbdt::GbdtParams;

fn main() {
    let rt = match LuRuntime::new("artifacts") {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            eprintln!("error: {e}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("== e2e: tuning the real Pallas blocked-LU kernel via PJRT ==");
    println!(
        "manifest: {} variants over sizes {:?}",
        rt.manifest.variants.len(),
        rt.manifest.sizes()
    );

    // Warm up (compile) every variant so measurements exclude compilation.
    for v in rt.manifest.variants.clone() {
        rt.prepare(v.n, v.block, v.tile).expect("compile variant");
    }
    println!("all variants compiled on the PJRT CPU client");

    let kernel = PallasLu::new(rt.clone());
    // The space is tiny (sizes x blocks x tiles), so a small budget of
    // real measurements suffices; every eval is a genuine execution.
    let config = MlkapsConfig {
        total_samples: 120,
        batch_size: 24,
        sampler: SamplerChoice::GaAdaptive,
        gbdt: GbdtParams { n_trees: 60, ..Default::default() },
        ga: Nsga2Params { pop_size: 12, generations: 10, ..Default::default() },
        opt_grid: 8,
        tree_depth: 4,
        threads: 1, // keep timing measurements interference-free
        seed: 3,
    };
    let model = Mlkaps::new(config).tune(&kernel);
    println!(
        "collected {} real measurements in {:.1}s",
        model.stats.samples, model.stats.sampling_secs
    );

    // Report the tuned (block, tile) per matrix size vs the naive default,
    // with REAL measured times.
    println!("\n  n    | tuned (block,tile) -> time     | default -> time    | speedup");
    let sizes = rt.manifest.sizes();
    let mut speedups = Vec::new();
    for (si, &n) in sizes.iter().enumerate() {
        let input = [si as f64];
        let tuned = model.predict(&input);
        let (tn, tb, tt) = kernel.variant_for(&input, &tuned);
        let t_tuned = rt.time_lu(tn, tb, tt, 5).expect("time tuned");
        let dflt = kernel.reference_design(&input).unwrap();
        let (dn, db, dt) = kernel.variant_for(&input, &dflt);
        let t_dflt = rt.time_lu(dn, db, dt, 5).expect("time default");
        let s = t_dflt / t_tuned;
        speedups.push(s);
        println!(
            "  {n:<4} | ({tb:>2},{tt:>2}) -> {:>9.3} ms | ({db:>2},{dt:>2}) -> {:>9.3} ms | x{s:.2}",
            t_tuned * 1e3,
            t_dflt * 1e3,
        );
    }
    let geo = mlkaps::util::stats::geomean(&speedups);
    println!("\ngeomean speedup of tuned tree over mid-table default: x{geo:.3}");

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/pallas_lu_tree.c", model.trees.to_c()).expect("write");
    println!("wrote results/pallas_lu_tree.c — the shippable runtime selector");
}
