//! The paper's headline experiment in miniature (§5.3): auto-tune the
//! Intel MKL dgetrf (LU) simulator on SPR with GA-Adaptive sampling and
//! report the speedup map over the expert hand-tuning.
//!
//! Run: `cargo run --release --example tune_dgetrf -- [--fast]`
//!      `--fast` shrinks the budget for a smoke run (~30 s).

use mlkaps::kernels::blas3sim::{Blas3Sim, FactKind};
use mlkaps::kernels::hardware::HardwareProfile;
use mlkaps::kernels::Kernel;
use mlkaps::pipeline::evaluate::SpeedupMap;
use mlkaps::pipeline::{Mlkaps, MlkapsConfig, SamplerChoice};
use mlkaps::report;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let (samples, val_grid) = if fast { (2_000, 16) } else { (15_000, 46) };

    let kernel = Blas3Sim::new(FactKind::Lu, HardwareProfile::spr(), 7);
    println!("== tuning {} ==", kernel.name());
    println!(
        "design space: {:.2e} configurations (paper: 4.6e13); sampling {samples}",
        kernel.design_space().cardinality().unwrap()
    );

    let config = MlkapsConfig {
        total_samples: samples,
        batch_size: 500,
        sampler: SamplerChoice::GaAdaptive,
        opt_grid: 16,
        tree_depth: 8,
        seed: 7,
        ..Default::default()
    };
    let model = Mlkaps::new(config).tune(&kernel);
    let st = &model.stats;
    println!(
        "pipeline: sampling {:.1}s | modeling {:.1}s | optimizing {:.1}s | model {}",
        st.sampling_secs,
        st.modeling_secs,
        st.optimizing_secs,
        report::human_bytes(st.model_bytes)
    );

    let map = SpeedupMap::build(&kernel, val_grid, &|input| model.predict(input));
    println!("\n{}", report::heatmap(&map));
    println!("vs MKL hand-tuning ({val_grid}x{val_grid} grid): {}", map.summary());
    println!("(paper, 30k samples: geomean x1.30, 85% progressions)");

    // Example learned configurations across the input space.
    println!("\nlearned configurations (nb, ib, threads, lookahead, decomp, rthresh, prefetch, dyn):");
    for input in [[1200.0, 1200.0], [3000.0, 3000.0], [4800.0, 1200.0], [1200.0, 4800.0]] {
        let d = model.predict(&input);
        let r = kernel.reference_design(&input).unwrap();
        println!(
            "  n={:>4} m={:>4}: mlkaps {:?} | mkl-ref {:?}",
            input[0],
            input[1],
            d.iter().map(|x| *x as i64).collect::<Vec<_>>(),
            r.iter().map(|x| *x as i64).collect::<Vec<_>>()
        );
    }

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/dgetrf_tree.c", model.trees.to_c()).expect("write tree");
    println!("\nwrote results/dgetrf_tree.c");
}
