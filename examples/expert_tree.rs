//! Expert-knowledge injection (§5.4.2 / Fig 12): combine a (deliberately
//! under-sampled) MLKAPS run with the MKL hand-tuning, taking the best of
//! both per input — all regressions disappear while the speedups remain.
//!
//! Run: `cargo run --release --example expert_tree`

use mlkaps::kernels::blas3sim::{Blas3Sim, FactKind};
use mlkaps::kernels::hardware::HardwareProfile;
use mlkaps::kernels::Kernel;
use mlkaps::pipeline::evaluate::SpeedupMap;
use mlkaps::pipeline::expert::ExpertModel;
use mlkaps::pipeline::{Mlkaps, MlkapsConfig, SamplerChoice};
use mlkaps::report;

fn main() {
    let kernel = Blas3Sim::new(FactKind::Qr, HardwareProfile::spr(), 11);
    println!("== expert tree on {} ==", kernel.name());

    // A modest 4k-sample run (the paper used a 15k run for Fig 12).
    let model = Mlkaps::new(MlkapsConfig {
        total_samples: 4_000,
        batch_size: 500,
        sampler: SamplerChoice::GaAdaptive,
        opt_grid: 16,
        tree_depth: 8,
        seed: 11,
        ..Default::default()
    })
    .tune(&kernel);

    let raw = SpeedupMap::build(&kernel, 24, &|input| model.predict(input));
    println!("\nMLKAPS alone:  {}", raw.summary());

    let expert = ExpertModel::combine(&kernel, &model, 3, 8);
    println!(
        "expert combination: MLKAPS won {:.0}% of grid points",
        expert.mlkaps_win_rate * 100.0
    );

    let combined = SpeedupMap::build(&kernel, 24, &|input| expert.predict(input));
    println!("expert tree:   {}", combined.summary());
    println!("\n{}", report::heatmap(&combined));
    println!("(paper Fig 12: all regressions removed, geomean x1.11)");
}
