//! Quickstart: tune the illustrative matrix-sum kernel of the paper's
//! Figs 1-2 — one design parameter (thread count T) against two input
//! parameters (n, m) — and emit the C decision tree a library would embed.
//!
//! Run: `cargo run --release --example quickstart`

use mlkaps::kernels::toy_sum::ToySum;
use mlkaps::kernels::Kernel;
use mlkaps::pipeline::evaluate::SpeedupMap;
use mlkaps::pipeline::{Mlkaps, MlkapsConfig, SamplerChoice};
use mlkaps::report;

fn main() {
    let kernel = ToySum::new(42);
    println!("== MLKAPS quickstart: tuning `{}` ==", kernel.name());
    println!(
        "inputs:  {:?}\ndesigns: {:?}",
        kernel.input_space().names(),
        kernel.design_space().names()
    );

    let config = MlkapsConfig {
        total_samples: 1500,
        batch_size: 250,
        sampler: SamplerChoice::GaAdaptive,
        opt_grid: 12,
        tree_depth: 6,
        seed: 42,
        ..Default::default()
    };
    let model = Mlkaps::new(config).tune(&kernel);
    let st = &model.stats;
    println!(
        "\npipeline: {} samples | sampling {:.1}s, modeling {:.1}s, optimizing {:.1}s",
        st.samples, st.sampling_secs, st.modeling_secs, st.optimizing_secs
    );

    // What did it learn? Small matrices -> few threads, large -> many.
    println!("\nlearned thread counts:");
    for (n, m) in [(64.0, 64.0), (512.0, 512.0), (2048.0, 2048.0), (8192.0, 8192.0)] {
        let t = model.predict(&[n, m])[0];
        let t_opt = kernel.optimal_threads(&[n, m]);
        println!("  {n:>5} x {m:<5} -> T = {t:<3} (analytic optimum {t_opt})");
    }

    // Validate against the fixed 16-thread reference on a 16x16 grid.
    let map = SpeedupMap::build(&kernel, 16, &|input| model.predict(input));
    println!("\n{}", report::heatmap(&map));
    println!("vs fixed T=16 reference: {}", map.summary());

    // The shippable artifact: C code.
    let c = model.trees.to_c();
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/quickstart_tree.c", &c).expect("write tree");
    println!(
        "\nwrote results/quickstart_tree.c ({} lines) — embed and call mlkaps_predict_config()",
        c.lines().count()
    );
}
