//! Head-to-head comparison of the three auto-tuners on one kernel:
//! MLKAPS (global surrogate + decision trees), Optuna-like (independent
//! per-input TPE+CMA-ES studies) and GPTune-like (multitask Bayesian
//! optimization + TLA2) — the §5.4 story in one binary.
//!
//! Run: `cargo run --release --example compare_autotuners`

use mlkaps::baselines::{GptuneLike, GptuneParams, OptunaLike, OptunaParams};
use mlkaps::kernels::toy_sum::ToySum;
use mlkaps::kernels::Kernel;
use mlkaps::pipeline::evaluate::SpeedupMap;
use mlkaps::pipeline::{Mlkaps, MlkapsConfig, SamplerChoice};
use mlkaps::report;
use mlkaps::util::telemetry::Stopwatch;

fn main() {
    let kernel = ToySum::new(99);
    let budget = 1024; // total kernel evaluations for every tuner
    let val_grid = 12;
    println!("== MLKAPS vs Optuna-like vs GPTune-like on `{}` ==", kernel.name());
    println!("equal budget: {budget} kernel evaluations each\n");

    // --- MLKAPS: one global budget, generalizes to ALL inputs via trees.
    let sw = Stopwatch::start();
    let mlkaps = Mlkaps::new(MlkapsConfig {
        total_samples: budget,
        batch_size: 128,
        sampler: SamplerChoice::GaAdaptive,
        opt_grid: 12,
        tree_depth: 6,
        seed: 1,
        ..Default::default()
    })
    .tune(&kernel);
    let t_mlkaps = sw.secs();

    // --- Optuna-like: the budget must be SPLIT across inputs (no
    // transfer); tune the same 12x12 grid the validation uses... which is
    // only 7 trials per input. This is the architectural handicap Fig 11
    // demonstrates.
    let inputs = kernel.input_space().grid(val_grid);
    let sw = Stopwatch::start();
    let optuna = OptunaLike::new(OptunaParams {
        trials_per_input: (budget / inputs.len()).max(1),
        threads: 8,
        ..Default::default()
    });
    let studies = optuna.optimize_grid(&kernel, &inputs);
    let t_optuna = sw.secs();

    // --- GPTune-like: 8 tasks sampled, TLA2 extrapolates to the rest.
    let sw = Stopwatch::start();
    let gptune = GptuneLike::new(GptuneParams {
        init_per_task: 8,
        total_budget: budget,
        ..Default::default()
    });
    let tasks: Vec<Vec<f64>> = kernel.input_space().grid(3); // 9 tasks
    let run = gptune.tune(&kernel, &tasks);
    let t_gptune = sw.secs();

    // --- Validate all three on the same grid vs the fixed reference.
    let m_mlkaps = SpeedupMap::build(&kernel, val_grid, &|i| mlkaps.predict(i));
    let m_optuna = SpeedupMap::build(&kernel, val_grid, &|i| {
        // Nearest-study lookup (Optuna has no generalization mechanism).
        let s = studies
            .iter()
            .min_by(|a, b| {
                let d = |s: &&mlkaps::baselines::optuna_like::StudyResult| {
                    (s.input[0] - i[0]).powi(2) + (s.input[1] - i[1]).powi(2)
                };
                d(a).partial_cmp(&d(b)).unwrap()
            })
            .unwrap();
        s.best_design.clone()
    });
    let m_gptune = SpeedupMap::build(&kernel, val_grid, &|i| gptune.tla2(&kernel, &run, i));

    let rows = vec![
        row("MLKAPS", &m_mlkaps, t_mlkaps, mlkaps.stats.model_bytes),
        row("Optuna-like", &m_optuna, t_optuna, 0),
        row("GPTune-like", &m_gptune, t_gptune, run.peak_model_bytes),
    ];
    println!(
        "{}",
        report::table(
            &["tuner", "geomean", "progressions", "min", "tuning-time", "model-mem"],
            &rows
        )
    );
    println!("(the paper: MLKAPS geomean x1.36 over Optuna on dgeqrf; GPTune OOMs at scale)");
}

fn row(
    name: &str,
    map: &SpeedupMap,
    secs: f64,
    mem: usize,
) -> Vec<String> {
    let s = map.summary();
    vec![
        name.into(),
        format!("x{:.3}", s.geomean),
        format!("{:.0}%", s.frac_progressions * 100.0),
        format!("x{:.2}", s.min),
        format!("{secs:.1}s"),
        report::human_bytes(mem),
    ]
}
